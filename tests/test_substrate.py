"""Substrate tests: optimizer, gradient compression, data pipeline,
checkpoint manager (atomicity, async, elastic restore), cost model."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import costmodel
from repro.data import DataConfig, TokenPipeline
from repro.optim import (
    AdamWConfig,
    apply_updates,
    grad_compress,
    init_state,
)


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = init_state(cfg, params)
        target = jnp.asarray([1.0, 2.0])
        for _ in range(200):
            grads = {"w": params["w"] - target}
            params, state, _ = apply_updates(cfg, params, grads, state,
                                             lr_scale=1.0)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_grad_clip(self):
        cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
        params = {"w": jnp.zeros(3)}
        state = init_state(cfg, params)
        big = {"w": jnp.full(3, 100.0)}
        _, _, metrics = apply_updates(cfg, params, big, state)
        assert float(metrics["grad_norm"]) > 100.0  # pre-clip norm reported

    def test_bf16_states_halve_memory(self):
        params = {"w": jnp.zeros((128, 128))}
        s32 = init_state(AdamWConfig(state_dtype="float32"), params)
        s16 = init_state(AdamWConfig(state_dtype="bfloat16"), params)
        assert s16["m"]["w"].dtype == jnp.bfloat16
        assert s32["m"]["w"].nbytes == 2 * s16["m"]["w"].nbytes

    def test_master_fp32_tracks(self):
        cfg = AdamWConfig(lr=0.01, master_fp32=True, weight_decay=0.0)
        params = {"w": jnp.zeros(4, jnp.bfloat16)}
        state = init_state(cfg, params)
        grads = {"w": jnp.full(4, 1e-3, jnp.bfloat16)}
        for _ in range(3):
            params, state, _ = apply_updates(cfg, params, grads, state)
        assert state["master"]["w"].dtype == jnp.float32
        assert params["w"].dtype == jnp.bfloat16


class TestGradCompression:
    def test_error_feedback_unbiased_over_steps(self):
        """With error feedback, the *cumulative* applied gradient converges
        to the cumulative true gradient (compression bias doesn't pile up).
        """
        rng = np.random.default_rng(0)
        g_true = jnp.asarray(rng.normal(size=(64,)) * 1e-3)
        err = grad_compress.init_error_state({"g": g_true})["g"]
        applied = jnp.zeros_like(g_true)
        for _ in range(50):
            q, scale = grad_compress.quantize(g_true + err)
            deq = grad_compress.dequantize(q, scale)
            err = (g_true + err) - deq
            applied = applied + deq
        np.testing.assert_allclose(
            np.asarray(applied), np.asarray(g_true * 50), rtol=0.02
        )

    def test_quantize_roundtrip_bound(self):
        g = jnp.asarray(np.random.default_rng(1).normal(size=(1000,)))
        q, scale = grad_compress.quantize(g)
        err = np.abs(np.asarray(grad_compress.dequantize(q, scale) - g))
        assert err.max() <= float(scale) * 0.5 + 1e-9


class TestDataPipeline:
    def test_deterministic_and_resumable(self):
        cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8, seed=3)
        p = TokenPipeline(cfg)
        a = np.asarray(p.batch_at(5)["tokens"])
        b = np.asarray(p.batch_at(5)["tokens"])  # constant-time re-fetch
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(a, np.asarray(p.batch_at(6)["tokens"]))

    def test_host_sharding_partitions_batch(self):
        cfg = DataConfig(vocab_size=128, seq_len=8, global_batch=8, seed=0)
        hosts = [TokenPipeline(cfg, host_id=i, n_hosts=4) for i in range(4)]
        slices = [np.asarray(h.batch_at(0)["tokens"]) for h in hosts]
        assert all(s.shape == (2, 8) for s in slices)
        # host slices are distinct (different fold_in)
        assert not np.array_equal(slices[0], slices[1])

    def test_indivisible_hosts_rejected(self):
        cfg = DataConfig(vocab_size=16, seq_len=4, global_batch=10)
        with pytest.raises(ValueError):
            TokenPipeline(cfg, host_id=0, n_hosts=4)


class TestCheckpointManager:
    def _tree(self, x=1.0):
        return {"params": {"w": jnp.full((4, 4), x)},
                "step": jnp.int32(7)}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        mgr.save(10, self._tree(2.5))
        restored, step = mgr.restore(None, self._tree(0.0))
        assert step == 10
        np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 2.5)

    def test_async_save_and_wait(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save_async(1, self._tree(1.0))
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_atomic_commit_ignores_stale_tmp(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(3, self._tree())
        # simulate a crashed save
        os.makedirs(str(tmp_path / "step_000000009.tmp"))
        mgr2 = CheckpointManager(str(tmp_path))  # re-open triggers GC
        assert mgr2.latest_step() == 3
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._tree(float(s)))
        assert mgr.all_steps() == [3, 4]

    def test_structure_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self._tree())
        with pytest.raises(ValueError, match="structure mismatch"):
            mgr.restore(1, {"only_one_leaf": jnp.zeros(3)})

    def test_elastic_reshard_restore(self, tmp_path):
        """A checkpoint written with one sharding restores under another
        (single host device here; the device_put path is what changes)."""
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(5, self._tree(3.0))
        shardings = jax.tree.map(
            lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]),
            self._tree(),
        )
        restored, _ = mgr.restore(5, self._tree(), shardings=shardings)
        np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 3.0)


class TestCostModel:
    HLO = """
  %x = bf16[256,4096]{1,0} all-gather(%a), replica_groups={}
  %y = f32[128]{0} all-reduce-start(%b), to_apply=%sum
  %yd = f32[128]{0} all-reduce-done(%y)
  %z = bf16[64,64]{1,0} reduce-scatter(%c)
  %w = (f32[32]{0}, f32[32]{0}) all-to-all(%d, %e)
  %p = bf16[16,16]{1,0} collective-permute(%f)
  %n = bf16[8,8]{1,0} add(%g, %h)
"""

    def test_parse_collectives(self):
        stats = costmodel.parse_collectives(self.HLO)
        assert stats.bytes_by_kind["all-gather"] == 256 * 4096 * 2
        assert stats.bytes_by_kind["all-reduce"] == 128 * 4
        assert stats.bytes_by_kind["reduce-scatter"] == 64 * 64 * 2
        assert stats.bytes_by_kind["all-to-all"] == 2 * 32 * 4
        assert stats.bytes_by_kind["collective-permute"] == 16 * 16 * 2
        assert stats.count_by_kind["all-reduce"] == 1  # -done not recounted

    def test_roofline_terms_from_compiled(self):
        f = jax.jit(lambda x: x @ x)
        c = f.lower(
            jax.ShapeDtypeStruct((256, 256), jnp.bfloat16)
        ).compile()
        rep = costmodel.roofline_from_compiled(c, n_devices=1,
                                               model_flops=2 * 256**3)
        assert rep.flops > 0 and rep.compute_s > 0
        assert rep.dominant in ("compute", "memory", "collective")
        assert 0.1 < rep.useful_ratio <= 1.5
