"""Elastic execution subsystem: wave-boundary checkpointable jobs +
preemptive regrant scheduling.

The load-bearing guarantees:

* preempt-at-every-wave-boundary-then-resume is **bit-exact** against
  every other execution mode — asserted by the ExecutionPlan
  mode-equivalence suite in ``tests/test_plan.py`` (the resumable path
  is a derivation of the same plan, so the property is structural);
* for the lexsort shuffle, results are bit-exact under *any* sequence of
  worker regrants (the canonical task-space buffers are grant-free);
* snapshots round-trip through the checkpoint manager (dtypes included)
  and respect ``keep=`` retention;
* the elastic simulator conserves workers through shrink/grow/suspend
  events, tiles each job's lifetime with segments (disk-queued time is
  its own ``suspended`` phase), and reproduces the base simulator when
  nothing regrants;
* a grant of **0** suspends a running job to disk; resume re-plans the
  remaining waves under any new grant, and on engine-oracle runs the
  charged checkpoint costs are *measured* save/load walls;
* ``predict-elastic`` strictly beats ``predict-deadline`` on deadline
  attainment under contention and is identical without it.
"""

from collections import Counter

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.cluster import (
    AnalyticOracle,
    Cluster,
    Dispatch,
    EngineOracle,
    Plan,
    SchedulingPolicy,
    assign_deadlines,
    generate_workload,
    get_policy,
)
from repro.elastic import (
    ElasticCluster,
    JobCursor,
    Regrant,
    RegrantCostModel,
    ResumableJob,
    WorkProgress,
    load_snapshot,
    run_resumable,
    save_snapshot,
)
from repro.mapreduce import (
    REDUCE_BACKENDS,
    JobConfig,
    collect_results,
    wordcount,
    wordcount_corpus,
)

ALL_REDUCE = sorted(REDUCE_BACKENDS)
ALL_SHUFFLE = ("lexsort", "all_to_all")

CORPUS = wordcount_corpus(360, vocab_size=53, seed=9)
APP = wordcount(53)
WANT = dict(Counter(np.asarray(CORPUS).tolist()))


def _cfg(**kw):
    kw.setdefault("num_mappers", 5)
    kw.setdefault("num_reducers", 3)
    kw.setdefault("num_workers", 2)
    kw.setdefault("capacity_factor", 8.0)
    return JobConfig(**kw)


def _outputs(job, state):
    ok, ov, dropped = job.result(state)
    return np.asarray(ok), np.asarray(ov), int(dropped)


class TestResumableEquivalence:
    """Regrant-specific equivalences.  The full mode-equivalence
    property suite (fused == traced == resumable at every preemption
    point, all backend combinations) lives in tests/test_plan.py — the
    resumable mode is one derivation of the same ExecutionPlan."""

    def test_plan_shared_with_fused_mode(self):
        """ResumableJob.from_plan shares the plan (and its stepper
        caches) with the fused mode it must match."""
        from repro.mapreduce import ExecutionPlan

        cfg = _cfg(num_mappers=6, num_reducers=4, num_workers=2)
        plan = ExecutionPlan(APP, cfg, len(CORPUS))
        ok_f, ov_f, d_f = plan.fused()(CORPUS)
        job = ResumableJob.from_plan(plan)
        assert job.plan is plan
        ok_r, ov_r, d_r = _outputs(job, run_resumable(job, CORPUS))
        assert np.array_equal(np.asarray(ok_f), ok_r)
        assert np.array_equal(np.asarray(ov_f), ov_r)
        assert int(d_f) == d_r

    @pytest.mark.parametrize("reduce_backend", ALL_REDUCE)
    def test_regrant_any_schedule_bit_exact_lexsort(self, reduce_backend):
        """Lexsort jobs may change W at every boundary and still match
        the fixed-grant run bit for bit (canonical task-space buffers)."""
        cfg = _cfg(reduce_backend=reduce_backend)
        job = ResumableJob(APP, cfg, len(CORPUS))
        ok0, ov0, d0 = _outputs(job, run_resumable(job, CORPUS))
        grants = [3, 1, 4, 2, 5, 3, 1]
        state = job.initial_state()
        i = 0
        while not state.cursor.done:
            state = job.regrant(state, grants[i % len(grants)])
            state = run_resumable(job, CORPUS, state=state,
                                  preempt_after=1)
            i += 1
        ok, ov, d = _outputs(job, state)
        assert np.array_equal(ok, ok0)
        assert np.array_equal(ov, ov0)
        assert d == d0

    def test_regrant_all_to_all_same_results(self):
        """The collective shuffle's partition layout is W-shaped, so a
        regrant before the barrier reshapes buffers — but with capacity
        headroom the *results* (collected key aggregates, zero drops)
        are identical."""
        cfg = _cfg(capacity_factor=10.0, shuffle_backend="all_to_all")
        job = ResumableJob(APP, cfg, len(CORPUS))
        state = run_resumable(job, CORPUS, preempt_after=2)
        state = job.regrant(state, 3)
        ok, ov, d = _outputs(job, run_resumable(job, CORPUS, state=state))
        assert d == 0
        assert collect_results(ok, ov) == WANT

    def test_result_before_done_raises(self):
        job = ResumableJob(APP, _cfg(), len(CORPUS))
        state = run_resumable(job, CORPUS, preempt_after=1)
        with pytest.raises(ValueError, match="not complete"):
            job.result(state)

    def test_step_after_done_raises(self):
        job = ResumableJob(APP, _cfg(), len(CORPUS))
        state = run_resumable(job, CORPUS)
        with pytest.raises(ValueError, match="complete"):
            job.step(state, CORPUS)


class TestSnapshotRoundTrip:
    @pytest.mark.parametrize("preempt_after", [1, 3, 4, 5])
    def test_manager_round_trip_resumes_bit_exact(self, tmp_path,
                                                  preempt_after):
        """Snapshot mid-map / at-barrier / mid-reduce through the
        checkpoint manager, restore template-free, resume — identical."""
        cfg = _cfg()
        job = ResumableJob(APP, cfg, len(CORPUS))
        ok0, ov0, d0 = _outputs(job, run_resumable(job, CORPUS))
        state = run_resumable(job, CORPUS, preempt_after=preempt_after)
        mgr = CheckpointManager(str(tmp_path), keep=3)
        step, save_s = save_snapshot(mgr, state)
        assert save_s >= 0.0
        restored, got_step, restore_s = load_snapshot(mgr)
        assert got_step == step == state.cursor.waves_executed
        assert restored.cursor == state.cursor
        for name, arr in state.arrays.items():
            got = restored.arrays[name]
            assert got.dtype == np.asarray(arr).dtype, name  # dtype gap
            assert np.array_equal(got, np.asarray(arr)), name
        ok, ov, d = _outputs(
            job, run_resumable(job, CORPUS, state=restored)
        )
        assert np.array_equal(ok, ok0)
        assert np.array_equal(ov, ov0)
        assert d == d0

    def test_restore_then_regrant_resumes_bit_exact(self, tmp_path):
        """The restore-side can re-plan under a different grant."""
        job = ResumableJob(APP, _cfg(), len(CORPUS))
        ok0, ov0, d0 = _outputs(job, run_resumable(job, CORPUS))
        state = run_resumable(job, CORPUS, preempt_after=2)
        mgr = CheckpointManager(str(tmp_path))
        save_snapshot(mgr, state)
        restored, _, _ = load_snapshot(mgr)
        restored = job.regrant(restored, 4)
        ok, ov, d = _outputs(
            job, run_resumable(job, CORPUS, state=restored)
        )
        assert np.array_equal(ok, ok0)
        assert np.array_equal(ov, ov0)
        assert d == d0

    def test_keep_retention_gc(self, tmp_path):
        """keep=2: successive wave snapshots GC oldest-first."""
        job = ResumableJob(APP, _cfg(), len(CORPUS))
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state = job.initial_state()
        for _ in range(4):
            state = run_resumable(job, CORPUS, state=state,
                                  preempt_after=1)
            save_snapshot(mgr, state)
        assert mgr.all_steps() == [3, 4]
        restored, step, _ = load_snapshot(mgr)
        assert step == 4 == restored.cursor.waves_executed

    def test_cursor_json_round_trip(self):
        job = ResumableJob(APP, _cfg(), len(CORPUS))
        cur = run_resumable(job, CORPUS, preempt_after=4).cursor
        assert JobCursor.from_json(cur.to_json()) == cur

    def test_cursor_version_gate(self):
        job = ResumableJob(APP, _cfg(), len(CORPUS))
        cur = job.initial_state().cursor
        bad = cur.to_json().replace('"_version": 1', '"_version": 99')
        with pytest.raises(ValueError, match="version"):
            JobCursor.from_json(bad)

    def test_foreign_cursor_rejected(self):
        job_a = ResumableJob(APP, _cfg(num_mappers=5), len(CORPUS))
        job_b = ResumableJob(APP, _cfg(num_mappers=7), len(CORPUS))
        state = job_a.run(CORPUS, preempt_after=1)
        with pytest.raises(ValueError, match="does not match"):
            job_b.run(CORPUS, state=state)


class TestRegrantCostModel:
    def test_remaining_fraction_requantizes(self):
        p = WorkProgress(mappers=16, reducers=8, map_tasks_done=8)
        # under W=8: 1 map wave + shuffle + 1 reduce wave of 4 total
        assert p.steps_remaining(8) == 3
        assert p.steps_total(8) == 4
        # under W=4: 2 map waves left of 7 total steps
        assert p.steps_remaining(4) == 5
        assert p.steps_total(4) == 7
        assert 0 < p.remaining_fraction(8) < 1

    def test_grow_worth_it_when_gain_beats_overhead(self):
        cm = RegrantCostModel(snapshot_overhead_s=0.01,
                              restore_overhead_s=0.01)
        p = WorkProgress(mappers=16, reducers=8)
        d = cm.evaluate(t_total_current=10.0, t_total_new=4.0,
                        progress=p, current_workers=2, new_workers=8)
        assert d.worth_it and d.gain_s > 0
        # overhead dominating a tiny remaining run kills the move
        d2 = cm.evaluate(t_total_current=0.01, t_total_new=0.004,
                         progress=p, current_workers=2, new_workers=8)
        assert not d2.worth_it

    def test_shrink_gates(self):
        cm = RegrantCostModel(snapshot_overhead_s=0.01,
                              restore_overhead_s=0.01,
                              min_remaining_frac=0.3,
                              max_overhead_frac=0.25)
        nearly_done = WorkProgress(
            mappers=16, reducers=8, map_tasks_done=16, shuffled=True,
            reduce_tasks_done=7,
        )
        d = cm.evaluate(t_total_current=10.0, t_total_new=12.0,
                        progress=nearly_done, current_workers=8,
                        new_workers=2)
        assert not d.shrink_ok  # almost finished: never checkpoint
        fresh = WorkProgress(mappers=16, reducers=8)
        d2 = cm.evaluate(t_total_current=10.0, t_total_new=12.0,
                         progress=fresh, current_workers=8, new_workers=2)
        assert d2.shrink_ok

    def test_measured_overhead_ewma(self):
        cm = RegrantCostModel(snapshot_overhead_s=0.1,
                              restore_overhead_s=0.1, ewma_alpha=0.5)
        cm.record_overhead(0.3, 0.5)
        assert cm.snapshot_overhead_s == pytest.approx(0.2)
        assert cm.restore_overhead_s == pytest.approx(0.3)
        assert cm.n_observed == 1


class TestAnalyticOracleRemaining:
    def test_zero_progress_sums_to_time(self):
        o = AnalyticOracle(noise=0.05, seed=3)
        t = o.time("wordcount", "jnp", 65536, 16, 12, 4, job_id=7)
        segs = o.remaining_segments(
            "wordcount", "jnp", 65536, 16, 12, 4, job_id=7
        )
        kinds = [k for k, _ in segs]
        assert kinds == ["map"] * 4 + ["shuffle"] + ["reduce"] * 3
        assert sum(s for _, s in segs) == pytest.approx(t, rel=1e-12)

    def test_remaining_monotone_in_progress(self):
        o = AnalyticOracle(noise=0.0)
        args = ("eximparse", "xla", 32768, 12, 8, 4)
        full = o.remaining_time(*args)
        mid = o.remaining_time(*args, map_tasks_done=8)
        post = o.remaining_time(*args, map_tasks_done=12, shuffled=True,
                                reduce_tasks_done=4)
        assert full > mid > post > 0

    def test_requantization_under_new_grant(self):
        """Remaining tasks re-wave under the new grant: half the mappers
        done, W doubles -> one map wave left instead of two."""
        o = AnalyticOracle(noise=0.0)
        segs_w4 = o.remaining_segments(
            "wordcount", "jnp", 65536, 16, 8, 4, map_tasks_done=8
        )
        segs_w8 = o.remaining_segments(
            "wordcount", "jnp", 65536, 16, 8, 8, map_tasks_done=8
        )
        assert [k for k, _ in segs_w4].count("map") == 2
        assert [k for k, _ in segs_w8].count("map") == 1


class _ScriptedElastic(SchedulingPolicy):
    """Dispatches each arrival at a fixed grant; shrinks job 0 when job 1
    arrives, grows it back when job 1 completes."""

    name = "scripted-elastic"

    def __init__(self):
        self.shrunk = False
        self.grown = False

    def prepare(self, cluster, apps):
        self.cluster = cluster

    def select(self, queue, free_workers, now):
        running = {v.job_id: v for v in self.cluster.running_jobs(now)}
        if queue and queue[0].job_id == 1 and not self.shrunk:
            v = running.get(0)
            if v is not None and v.pending_workers is None:
                self.shrunk = True
                return Regrant(0, 2, reason="scripted shrink")
        if queue:
            plan = Plan(backend="jnp", mappers=16, reducers=8,
                        workers=min(8, free_workers) or 1)
            if plan.workers > free_workers:
                return None
            return Dispatch(queue[0], plan)
        return None

    def idle(self, free_workers, now):
        if self.grown or not self.shrunk:
            return None
        v = {u.job_id: u for u in self.cluster.running_jobs(now)}.get(0)
        if (
            v is not None and v.pending_workers is None
            and v.workers == 2 and v.steps_remaining >= 2
            and free_workers >= 6
        ):
            self.grown = True
            return Regrant(0, 8, reason="scripted grow")
        return None


class TestElasticClusterSim:
    def _jobs(self, n=2, gap=0.15, size=1 << 17):
        return generate_workload(
            n, seed=5, arrival="uniform", mean_interarrival=gap,
            size_range=(size, size),
        )

    def test_scripted_shrink_grow_accounting(self):
        oracle = AnalyticOracle(noise=0.0)
        cluster = ElasticCluster(
            12, oracle, snapshot_overhead_s=0.01, restore_overhead_s=0.02
        )
        policy = _ScriptedElastic()
        result = cluster.run(self._jobs(), policy)
        assert policy.shrunk and policy.grown
        rec = result.records[0]
        assert rec.n_regrants == 2
        assert rec.overhead_s == pytest.approx(2 * 0.03)
        # segments tile [start, finish] with overhead-sized gaps only
        assert rec.segments[0][0] == rec.start
        assert rec.segments[-1][1] == rec.finish
        grants = [w for _, _, w in rec.segments]
        assert grants == [8, 2, 8]
        for (_, t1, _), (t2, _, _) in zip(rec.segments, rec.segments[1:]):
            assert t2 - t1 == pytest.approx(0.03)
        # both jobs completed exactly once; worker accounting conserved
        assert all(r.completed for r in result.records)
        m = result.metrics()
        assert m["n_regrants"] == 2
        assert m["n_preempted_jobs"] == 1
        assert m["regrant_overhead_s"] == pytest.approx(0.06)

    def test_synthesized_trace_segments_and_conservation(self):
        oracle = AnalyticOracle(noise=0.0)
        cluster = ElasticCluster(12, oracle)
        result = cluster.run(self._jobs(), _ScriptedElastic())
        trace = result.records[0].trace
        assert trace is not None
        times = trace.phase_times()
        assert times.get("regrant", 0.0) == pytest.approx(0.08)
        assert set(times) >= {"map", "shuffle", "reduce", "regrant"}
        # phase walls (including overhead) sum to the turnaround
        assert trace.check_conservation(time_rel_tol=1e-9,
                                        time_abs_tol=1e-9) == []
        assert trace.total_s == pytest.approx(
            result.records[0].true_time
        )

    def test_no_regrant_policy_matches_base_cluster(self):
        """With no elastic actions the elastic simulator reproduces the
        base event loop's schedule."""
        jobs = generate_workload(
            25, seed=3, arrival="bursty", mean_interarrival=0.1,
            size_range=(1 << 14, 1 << 17),
        )
        oracle = AnalyticOracle(noise=0.02, seed=3)
        jobs = assign_deadlines(
            jobs, lambda j: oracle.nominal_time(j.app, j.size),
            slack_range=(1.5, 4.0), fraction=0.5, seed=4,
        )
        m_base = Cluster(12, AnalyticOracle(noise=0.02, seed=3)).run(
            jobs, get_policy("predict-deadline", seed=3)
        ).metrics()
        m_el = ElasticCluster(12, AnalyticOracle(noise=0.02, seed=3)).run(
            jobs, get_policy("predict-deadline", seed=3)
        ).metrics()
        assert m_el["n_regrants"] == 0
        assert m_el["makespan_s"] == pytest.approx(
            m_base["makespan_s"], rel=1e-9
        )
        assert m_el["slo_attainment"] == m_base["slo_attainment"]
        assert m_el["n_rejected"] == m_base["n_rejected"]

    def test_inelastic_oracle_rejected(self):
        class NoSegments:
            platform = "x"

            def time(self, *a, **k):
                return 1.0

        with pytest.raises(TypeError, match="remaining_segments"):
            ElasticCluster(4, NoSegments())

    def test_invalid_regrants_raise(self):
        oracle = AnalyticOracle(noise=0.0)
        cluster = ElasticCluster(12, oracle)

        class Bad(SchedulingPolicy):
            name = "bad-elastic"

            def __init__(self, action):
                self.action = action
                self.sent = False
                self.dispatched = False

            def prepare(self, cluster, apps):
                self.cluster = cluster

            def select(self, queue, free, now):
                if not self.dispatched:
                    self.dispatched = True
                    return Dispatch(
                        queue[0],
                        Plan(backend="jnp", mappers=16, reducers=8,
                             workers=8),
                    )
                if not self.sent:
                    self.sent = True
                    return self.action
                return None

        jobs = self._jobs(n=2, gap=0.1)
        with pytest.raises(ValueError, match="not running"):
            cluster.run(jobs, Bad(Regrant(99, 2)))
        with pytest.raises(ValueError, match="no-op"):
            ElasticCluster(12, oracle).run(jobs, Bad(Regrant(0, 8)))
        with pytest.raises(ValueError, match="free"):
            ElasticCluster(12, oracle).run(jobs, Bad(Regrant(0, 100)))

    def test_regrant_action_validation(self):
        with pytest.raises(ValueError, match="bad regrant"):
            Regrant(0, -1)
        Regrant(0, 0)  # grant 0 == suspend-to-disk: legal


class _ScriptedSuspend(SchedulingPolicy):
    """Dispatches at a fixed grant; suspends job 0 to disk when job 1
    arrives, resumes it once the pool quiets down."""

    name = "scripted-suspend"

    def __init__(self, resume_workers=8):
        self.resume_workers = resume_workers
        self.suspended = False
        self.resumed = False
        self.overheads: list[tuple[float, float]] = []

    def prepare(self, cluster, apps):
        self.cluster = cluster

    def observe_overhead(self, save_s, restore_s):
        self.overheads.append((save_s, restore_s))

    def select(self, queue, free, now):
        if queue and queue[0].job_id == 1 and not self.suspended:
            v = {u.job_id: u for u in self.cluster.running_jobs(now)}.get(0)
            if (v is not None and v.pending_workers is None
                    and v.steps_remaining >= 2):
                self.suspended = True
                return Regrant(0, 0, reason="scripted suspend")
        if queue:
            plan = Plan(backend="jnp", mappers=16, reducers=8,
                        workers=min(8, free) or 1)
            if plan.workers > free:
                return None
            return Dispatch(queue[0], plan)
        return None

    def idle(self, free, now):
        if self.resumed or not self.suspended:
            return None
        sus = self.cluster.suspended_jobs()
        if sus and free >= self.resume_workers:
            self.resumed = True
            return Regrant(sus[0].job_id, self.resume_workers,
                           reason="scripted resume")
        return None


class TestSuspendToDisk:
    def _jobs(self, n=2, gap=0.15, size=1 << 17):
        return generate_workload(
            n, seed=5, arrival="uniform", mean_interarrival=gap,
            size_range=(size, size),
        )

    def test_scripted_suspend_resume_accounting(self):
        oracle = AnalyticOracle(noise=0.0)
        cluster = ElasticCluster(
            8, oracle, snapshot_overhead_s=0.01, restore_overhead_s=0.02
        )
        policy = _ScriptedSuspend()
        result = cluster.run(self._jobs(), policy)
        assert policy.suspended and policy.resumed
        rec = result.records[0]
        assert rec.n_suspends == 1 and rec.n_regrants == 2
        # Suspend charges the snapshot, resume the restore.
        assert rec.overhead_s == pytest.approx(0.03)
        # The suspended gap separates the two execution segments; the
        # full grant was free in between (job 1 ran at 8 workers).
        assert len(rec.segments) == 2
        grants = [w for _, _, w in rec.segments]
        assert grants == [8, 8]
        assert rec.segments[1][0] > rec.segments[0][1]
        assert all(r.completed for r in result.records)
        m = result.metrics()
        assert m["n_suspends"] == 1
        assert m["n_regrants"] == 2

    def test_suspended_trace_tiles_turnaround(self):
        oracle = AnalyticOracle(noise=0.0)
        cluster = ElasticCluster(8, oracle)
        result = cluster.run(self._jobs(), _ScriptedSuspend())
        trace = result.records[0].trace
        times = trace.phase_times()
        assert times.get("suspended", 0.0) > 0
        assert times.get("regrant", 0.0) == pytest.approx(0.04)
        # Phase walls (work + overhead + disk queue) tile the turnaround.
        assert trace.check_conservation(time_rel_tol=1e-9,
                                        time_abs_tol=1e-9) == []
        assert trace.counter("suspended", "events") == 1

    def test_suspended_view_exposes_progress(self):
        oracle = AnalyticOracle(noise=0.0)
        cluster = ElasticCluster(8, oracle)

        class Peek(_ScriptedSuspend):
            views = None

            def idle(self, free, now):
                sus = self.cluster.suspended_jobs()
                if sus and Peek.views is None:
                    Peek.views = sus
                return super().idle(free, now)

        cluster.run(self._jobs(), Peek())
        (view,) = Peek.views
        assert view.job_id == 0
        assert view.workers_before == 8
        assert not view.progress.done
        assert view.progress.map_tasks_done > 0

    def test_unresumed_suspension_is_stranding(self):
        """A policy that suspends and never resumes must fail loudly,
        not spin or silently drop the job."""

        class NeverResume(_ScriptedSuspend):
            def idle(self, free, now):
                return None

        oracle = AnalyticOracle(noise=0.0)
        cluster = ElasticCluster(8, oracle)
        with pytest.raises(RuntimeError, match="suspended"):
            cluster.run(self._jobs(), NeverResume())

    def test_resume_validation(self):
        """Resume of a suspended job demands workers >= 1 and a grant
        that fits the free pool."""

        class BadResume(_ScriptedSuspend):
            def __init__(self, workers):
                super().__init__()
                self.bad_workers = workers

            def idle(self, free, now):
                if self.suspended and not self.resumed:
                    sus = self.cluster.suspended_jobs()
                    if sus:
                        self.resumed = True
                        return Regrant(sus[0].job_id, self.bad_workers)
                return None

        with pytest.raises(ValueError, match="workers >= 1"):
            ElasticCluster(8, AnalyticOracle(noise=0.0)).run(
                self._jobs(), BadResume(0)
            )
        with pytest.raises(ValueError, match="free"):
            ElasticCluster(8, AnalyticOracle(noise=0.0)).run(
                self._jobs(), BadResume(100)
            )

    def test_predict_elastic_suspend_rescues_floor_victims(self):
        """When every best-effort victim already sits at the shrink
        floor, only a suspend can free workers for a starved deadline
        job — the suspend=True policy does it and the job is resumed
        and completed later."""
        oracle = AnalyticOracle(noise=0.02, seed=1)
        jobs = generate_workload(
            30, seed=1, arrival="bursty", mean_interarrival=0.06,
            size_range=(1 << 15, 1 << 18),
        )
        jobs = assign_deadlines(
            jobs, lambda j: oracle.nominal_time(j.app, j.size),
            slack_range=(1.1, 2.0), fraction=0.5, seed=2,
        )
        policy = get_policy("predict-elastic", seed=1, suspend=True,
                            shrink_floor=4, worker_grid=(4, 8))
        result = ElasticCluster(8, oracle).run(jobs, policy)
        m = result.metrics()
        assert policy.n_suspends > 0 and policy.n_resumes > 0
        assert m["n_suspends"] >= policy.n_suspends
        # Every suspended job finished (none stranded on disk).
        assert all(
            r.completed for r in result.records if r.n_suspends > 0
        )

    def test_suspend_resume_not_gated_on_regrow(self):
        """Resume is a liveness obligation, not an optimization: with
        regrow=False a suspended job must still come back (a policy that
        suspends without a resume path strands the whole run)."""
        oracle = AnalyticOracle(noise=0.02, seed=1)
        jobs = generate_workload(
            30, seed=1, arrival="bursty", mean_interarrival=0.06,
            size_range=(1 << 15, 1 << 18),
        )
        jobs = assign_deadlines(
            jobs, lambda j: oracle.nominal_time(j.app, j.size),
            slack_range=(1.1, 2.0), fraction=0.5, seed=2,
        )
        policy = get_policy("predict-elastic", seed=1, suspend=True,
                            regrow=False, shrink_floor=4,
                            worker_grid=(4, 8))
        result = ElasticCluster(8, oracle).run(jobs, policy)
        assert policy.n_suspends > 0 and policy.n_resumes > 0
        assert all(
            r.completed for r in result.records if r.n_suspends > 0
        )


class TestMeasuredOverheadScheduling:
    def test_analytic_oracle_keeps_configured_costs(self):
        """No regrant_overhead on the oracle -> configured costs charged
        (the pre-existing contract, asserted bit-for-bit above)."""
        oracle = AnalyticOracle(noise=0.0)
        assert not hasattr(oracle, "regrant_overhead")
        cluster = ElasticCluster(
            12, oracle, snapshot_overhead_s=0.01, restore_overhead_s=0.02
        )
        assert cluster._measure_overhead is None

    @pytest.mark.slow
    def test_engine_oracle_measures_real_snapshot_walls(self):
        oracle = EngineOracle(warmup=0, size_quantum=1024)
        save_s, restore_s = oracle.regrant_overhead(
            "wordcount", "jnp", 4096, 4, 2
        )
        assert save_s > 0 and restore_s > 0
        # Post-shuffle snapshots have a different layout; still measured.
        save2, restore2 = oracle.regrant_overhead(
            "wordcount", "jnp", 4096, 4, 2, shuffled=True
        )
        assert save2 > 0 and restore2 > 0

    @pytest.mark.slow
    def test_elastic_sim_charges_measured_overheads(self):
        """On an engine-oracle run, the regrant gap equals the measured
        save+restore walls and the policy's cost-model EWMA ingests the
        pair — measured, not configured, checkpoint costs."""
        oracle = EngineOracle(warmup=0, size_quantum=1024)
        # Configured costs deliberately absurd: they must NOT be charged.
        cluster = ElasticCluster(
            8, oracle, snapshot_overhead_s=99.0, restore_overhead_s=99.0
        )
        import dataclasses as _dc

        jobs = [
            _dc.replace(j, arrival=0.0) for j in generate_workload(
                2, seed=5, arrival="uniform", mean_interarrival=0.001,
                size_range=(2048, 2048),
            )
        ]
        # Both jobs arrive together: job 1 is queued the moment job 0
        # dispatches, so the scripted suspend fires deterministically
        # (no dependence on wall-clocked segment durations).
        policy = _ScriptedSuspend(resume_workers=4)
        result = cluster.run(jobs, policy)
        rec = result.records[0]
        assert policy.suspended and policy.resumed
        assert policy.overheads, "observe_overhead hook never called"
        save_s, restore_s = policy.overheads[0]
        assert 0 < save_s < 1 and 0 < restore_s < 1
        assert rec.overhead_s == pytest.approx(save_s + restore_s)

    def test_cost_model_hook_on_predict_elastic(self):
        """predict-elastic wires observe_overhead to its cost model."""
        from repro.cluster.policies import ElasticDeadline

        policy = ElasticDeadline(seed=0)
        oracle = AnalyticOracle(noise=0.0)
        policy.prepare(ElasticCluster(8, oracle), [])
        before = policy.cost_model.n_observed
        policy.observe_overhead(0.5, 0.25)
        assert policy.cost_model.n_observed == before + 1


class TestPredictElasticPolicy:
    CONTENDED = dict(arrival="bursty", mean_interarrival=0.08,
                     slack=(1.1, 2.2), frac=0.5, workers=12, n=50)
    UNCONTENDED = dict(arrival="poisson", mean_interarrival=1.0,
                       slack=(2.5, 6.0), frac=0.5, workers=12, n=30)

    def _run(self, policy_name, *, arrival, mean_interarrival, slack,
             frac, workers, n, seed=1):
        oracle = AnalyticOracle(noise=0.02, seed=seed)
        jobs = generate_workload(
            n, seed=seed, arrival=arrival,
            mean_interarrival=mean_interarrival,
            size_range=(1 << 14, 1 << 18),
        )
        jobs = assign_deadlines(
            jobs, lambda j: oracle.nominal_time(j.app, j.size),
            slack_range=slack, fraction=frac, seed=seed + 1,
        )
        policy = get_policy(policy_name, seed=seed)
        metrics = ElasticCluster(workers, oracle).run(
            jobs, policy
        ).metrics()
        return metrics, policy

    def test_contended_strictly_better_slo(self):
        m_d, _ = self._run("predict-deadline", **self.CONTENDED)
        m_e, pol = self._run("predict-elastic", **self.CONTENDED)
        assert m_e["n_regrants"] > 0 and pol.n_shrinks > 0
        assert m_e["slo_attainment"] > m_d["slo_attainment"]

    def test_uncontended_identical_to_deadline(self):
        m_d, _ = self._run("predict-deadline", **self.UNCONTENDED)
        m_e, _ = self._run("predict-elastic", **self.UNCONTENDED)
        assert m_e["n_regrants"] == 0
        assert m_e["makespan_s"] == pytest.approx(
            m_d["makespan_s"], rel=1e-12
        )
        assert m_e["slo_attainment"] == m_d["slo_attainment"]

    def test_interrupted_traces_feed_phase_refits(self):
        """Completed preempted jobs carry segment-summed traces that the
        online refiner accepts (per-phase models keep fitting)."""
        m_e, pol = self._run("predict-elastic", **self.CONTENDED)
        assert pol.n_shrinks > 0
        assert pol.refiner.n_phase_refits > 0

    def test_plain_cluster_degrades_to_deadline(self):
        jobs = generate_workload(
            20, seed=2, arrival="poisson", mean_interarrival=0.15,
            size_range=(1 << 14, 1 << 17),
        )
        oracle = AnalyticOracle(noise=0.02, seed=2)
        jobs = assign_deadlines(
            jobs, lambda j: oracle.nominal_time(j.app, j.size),
            slack_range=(1.5, 4.0), fraction=0.5, seed=3,
        )
        m_d = Cluster(12, AnalyticOracle(noise=0.02, seed=2)).run(
            jobs, get_policy("predict-deadline", seed=2)
        ).metrics()
        m_e = Cluster(12, AnalyticOracle(noise=0.02, seed=2)).run(
            jobs, get_policy("predict-elastic", seed=2)
        ).metrics()
        for key in ("makespan_s", "slo_attainment", "n_rejected"):
            assert m_e[key] == m_d[key]


@pytest.mark.slow
class TestEngineOracleWaveStepping:
    def test_remaining_time_shrinks_with_progress(self):
        oracle = EngineOracle(warmup=0, size_quantum=1024)
        args = ("wordcount", "jnp", 4096, 4, 2, 2)
        segs = oracle.remaining_segments(*args)
        kinds = [k for k, _ in segs]
        assert kinds == ["map", "map", "shuffle", "reduce"]
        assert all(t > 0 for _, t in segs)
        partial = oracle.remaining_time(*args, map_tasks_done=4,
                                        shuffled=True)
        assert partial > 0
        assert len(
            oracle.remaining_segments(*args, map_tasks_done=4,
                                      shuffled=True)
        ) == 1

    def test_elastic_cluster_on_engine_oracle(self):
        """The elastic simulator runs end-to-end on the wave-stepping
        engine oracle (tiny trace, fifo-static: no bootstrap sweep)."""
        oracle = EngineOracle(warmup=0, size_quantum=1024)
        jobs = generate_workload(
            3, seed=1, arrival="uniform", mean_interarrival=0.05,
            size_range=(2048, 4096),
        )
        result = ElasticCluster(4, oracle).run(
            jobs, get_policy("fifo-static", mappers=4, reducers=4,
                             workers=2)
        )
        assert all(r.completed for r in result.records)

    def test_engine_sharded_oracle_per_phase_traces(self):
        """The engine-sharded oracle schedules the real shard_map mesh
        mode (W=1 mesh in-process; multi-device covered by the sharded
        subprocess test) and completed jobs carry per-phase wall times
        measured on that path."""
        oracle = EngineOracle(warmup=0, size_quantum=1024, traced=True,
                              sharded=True)
        assert oracle.platform == "engine-sharded"
        jobs = generate_workload(
            2, seed=1, arrival="uniform", mean_interarrival=0.05,
            size_range=(2048, 4096),
        )
        result = ElasticCluster(2, oracle).run(
            jobs, get_policy("fifo-static", mappers=4, reducers=4,
                             workers=1)
        )
        assert all(r.completed for r in result.records)
        for rec in result.records:
            times = rec.trace.phase_times()
            assert set(times) >= {"map", "shuffle", "reduce"}
            assert all(v > 0 for v in times.values())
            assert rec.trace.check_conservation() == []

    def test_engine_sharded_oracle_rejects_oversized_grant(self):
        oracle = EngineOracle(warmup=0, sharded=True)
        import jax

        too_many = len(jax.devices()) + 1
        with pytest.raises(ValueError, match="devices"):
            oracle.time("wordcount", "jnp", 2048, 4, 2, too_many)
