"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode on CPU; TPU is the deployment target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.rwkv6 import wkv6, wkv6_ref
from repro.kernels.local_reduce import local_reduce, local_reduce_ref
from repro.kernels.segment_reduce import (
    PAD_KEY,
    segment_reduce,
    segment_reduce_ref,
)

RNG = np.random.default_rng(0)


def _randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("B,Sq,Sk,Hq,nkv,hd,causal", [
        (2, 128, 128, 4, 2, 64, True),
        (1, 256, 256, 8, 8, 128, True),
        (2, 100, 100, 4, 1, 32, True),     # ragged seq -> padding path
        (1, 64, 192, 2, 2, 80, False),     # Sk > Sq, odd head_dim
        (1, 128, 128, 16, 2, 128, True),   # deep GQA grouping
    ])
    def test_matches_reference(self, B, Sq, Sk, Hq, nkv, hd, causal):
        q = _randn((B, Sq, Hq, hd))
        k = _randn((B, Sk, nkv, hd))
        v = _randn((B, Sk, nkv, hd))
        out = flash_attention(q, k, v, causal=causal)
        ref = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_bfloat16(self):
        q = _randn((1, 128, 4, 64), jnp.bfloat16)
        k = _randn((1, 128, 2, 64), jnp.bfloat16)
        v = _randn((1, 128, 2, 64), jnp.bfloat16)
        out = flash_attention(q, k, v, causal=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=5e-2, atol=5e-2,
        )

    @given(
        sq=st.integers(8, 160), hq=st.sampled_from([1, 2, 4, 8]),
        g=st.sampled_from([1, 2, 4]), hd=st.sampled_from([16, 32, 64]),
        bq=st.sampled_from([16, 32, 128]),
    )
    @settings(max_examples=8, deadline=None)
    def test_property_block_shape_invariance(self, sq, hq, g, hd, bq):
        """Output must not depend on the BlockSpec tiling."""
        q = _randn((1, sq, hq * g, hd))
        k = _randn((1, sq, hq, hd))
        v = _randn((1, sq, hq, hd))
        a = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bq)
        b = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=3e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("B,Sq,S_max,Hq,nkv,hd,kv_len", [
        (2, 1, 256, 4, 2, 64, 100),
        (1, 1, 1024, 8, 8, 128, 1024),
        (2, 4, 512, 4, 1, 32, 300),
        (1, 1, 96, 2, 2, 80, 7),
    ])
    def test_matches_reference(self, B, Sq, S_max, Hq, nkv, hd, kv_len):
        q = _randn((B, Sq, Hq, hd))
        k = _randn((B, S_max, nkv, hd))
        v = _randn((B, S_max, nkv, hd))
        out = decode_attention(q, k, v, kv_len, block_k=128)
        ref = decode_attention_ref(q, k, v, kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_garbage_beyond_kv_len_ignored(self):
        q = _randn((1, 1, 2, 32))
        k = _randn((1, 128, 2, 32))
        v = _randn((1, 128, 2, 32))
        out1 = decode_attention(q, k, v, 50, block_k=128)
        k2 = k.at[:, 50:].set(1e4)  # poison unwritten slots
        v2 = v.at[:, 50:].set(-1e4)
        out2 = decode_attention(q, k2, v2, 50, block_k=128)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-6)


class TestWKV6:
    @pytest.mark.parametrize("B,T,H,hs,chunk", [
        (2, 64, 2, 32, 16),
        (1, 100, 4, 64, 32),   # ragged T -> padding path
        (2, 32, 1, 16, 32),
        (1, 128, 2, 64, 64),
    ])
    def test_matches_step_scan(self, B, T, H, hs, chunk):
        r = _randn((B, T, H, hs))
        k = _randn((B, T, H, hs), scale=0.5)
        v = _randn((B, T, H, hs))
        w = jnp.asarray(RNG.uniform(0.05, 0.999, (B, T, H, hs)), jnp.float32)
        u = _randn((H, hs), scale=0.3)
        out, S = wkv6(r, k, v, w, u, chunk=chunk)
        ref_out, ref_S = wkv6_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(S), np.asarray(ref_S),
                                   rtol=2e-3, atol=2e-3)

    def test_strong_decay_no_overflow(self):
        """w near 0 (log-space danger zone) must stay finite."""
        B, T, H, hs = 1, 64, 1, 16
        r = _randn((B, T, H, hs))
        k = _randn((B, T, H, hs))
        v = _randn((B, T, H, hs))
        w = jnp.full((B, T, H, hs), 1e-6, jnp.float32)
        u = _randn((H, hs))
        out, S = wkv6(r, k, v, w, u, chunk=16)
        assert np.isfinite(np.asarray(out)).all()
        assert np.isfinite(np.asarray(S)).all()


class TestSegmentReduce:
    @pytest.mark.parametrize("R,C,nkeys", [(3, 64, 10), (1, 128, 5),
                                           (4, 32, 32), (2, 256, 100)])
    def test_matches_reference(self, R, C, nkeys):
        keys = np.sort(
            RNG.integers(0, nkeys, size=(R, C)).astype(np.int32), axis=1
        )
        for r in range(R):
            npad = int(RNG.integers(0, C // 3))
            if npad:
                keys[r, -npad:] = int(PAD_KEY)
            keys[r] = np.sort(keys[r])
        vals = RNG.integers(1, 10, size=(R, C)).astype(np.int32)
        ok, ov = segment_reduce(jnp.asarray(keys), jnp.asarray(vals))
        for r in range(R):
            rk, rv = segment_reduce_ref(jnp.asarray(keys[r]),
                                        jnp.asarray(vals[r]))
            np.testing.assert_array_equal(np.asarray(ok[r]), np.asarray(rk))
            np.testing.assert_array_equal(np.asarray(ov[r]), np.asarray(rv))

    @given(
        c=st.sampled_from([16, 64, 128]),
        nkeys=st.integers(1, 40),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_sum_conservation(self, c, nkeys, seed):
        rng = np.random.default_rng(seed)
        keys = np.sort(rng.integers(0, nkeys, c).astype(np.int32))
        vals = rng.integers(0, 100, c).astype(np.int32)
        ok, ov = segment_reduce(jnp.asarray(keys), jnp.asarray(vals))
        assert int(np.asarray(ov).sum()) == int(vals.sum())
        # one output slot per distinct key
        assert (np.asarray(ok) != int(PAD_KEY)).sum() == len(set(keys))


class TestLocalReduce:
    """Map-side combine kernel: dense front-packed aggregates vs the
    scan-based reference, same PAD_KEY convention as segment_reduce."""

    @pytest.mark.parametrize("N,C,nkeys", [(4, 64, 7), (1, 128, 3),
                                           (8, 32, 32), (2, 256, 100)])
    def test_matches_reference(self, N, C, nkeys):
        keys = RNG.integers(0, nkeys, size=(N, C)).astype(np.int32)
        for r in range(N):
            npad = int(RNG.integers(0, C // 3))
            if npad:
                keys[r, -npad:] = int(PAD_KEY)
            keys[r] = np.sort(keys[r])
        vals = RNG.integers(1, 10, size=(N, C)).astype(np.int32)
        ok, ov = local_reduce(jnp.asarray(keys), jnp.asarray(vals))
        for r in range(N):
            rk, rv = local_reduce_ref(jnp.asarray(keys[r]),
                                      jnp.asarray(vals[r]))
            np.testing.assert_array_equal(np.asarray(ok[r]), np.asarray(rk))
            np.testing.assert_array_equal(np.asarray(ov[r]), np.asarray(rv))

    def test_all_pad_rows(self):
        """Empty task rows (a mapper past the corpus tail) compact to an
        all-(PAD_KEY, 0) row, not garbage."""
        keys = jnp.full((2, 64), int(PAD_KEY), jnp.int32)
        vals = jnp.ones((2, 64), jnp.int32)
        ok, ov = local_reduce(keys, vals)
        assert (np.asarray(ok) == int(PAD_KEY)).all()
        assert (np.asarray(ov) == 0).all()

    @given(
        c=st.sampled_from([16, 64, 128]),
        nkeys=st.integers(1, 40),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_front_packed_sum_conserved(self, c, nkeys, seed):
        """The contraction contract the shuffle relies on: one slot per
        distinct key, front-packed ascending, dead tail, sum conserved."""
        rng = np.random.default_rng(seed)
        keys = np.sort(rng.integers(0, nkeys, c).astype(np.int32))
        vals = rng.integers(0, 100, c).astype(np.int32)
        ok, ov = local_reduce(jnp.asarray(keys), jnp.asarray(vals))
        ok, ov = np.asarray(ok), np.asarray(ov)
        n = int((ok != int(PAD_KEY)).sum())
        assert n == len(set(keys.tolist()))
        np.testing.assert_array_equal(ok[:n], np.unique(keys))
        assert (ok[n:] == int(PAD_KEY)).all() and (ov[n:] == 0).all()
        assert int(ov.sum()) == int(vals.sum())
