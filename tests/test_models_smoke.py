"""Per-arch smoke tests: reduced same-family config, one real train/forward
step on CPU, shape + NaN assertions; decode/prefill consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    ARCH_IDS,
    SHAPES,
    applicable_shapes,
    concrete_batch,
    get_config,
    smoke_config,
)
from repro.models import transformer as tf

SMALL = dataclasses.replace(SHAPES["train_4k"], seq_len=24, global_batch=2)


@pytest.fixture(scope="module")
def smoke_models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = smoke_config(arch)
            params = tf.init_params(cfg, jax.random.PRNGKey(0))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_finite(arch, smoke_models):
    cfg, params = smoke_models(arch)
    batch = concrete_batch(cfg, SMALL)
    loss, grads = jax.value_and_grad(
        lambda p: tf.loss_fn(p, cfg, batch)
    )(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.isfinite(np.asarray(g, np.float32)).all()
                          for g in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_output_shape(arch, smoke_models):
    cfg, params = smoke_models(arch)
    batch = concrete_batch(cfg, SMALL)
    logits, _ = tf.forward(params, cfg, batch)
    B = SMALL.global_batch
    if cfg.family == "vlm":
        S = SMALL.seq_len  # patches + text
    else:
        S = SMALL.seq_len
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_padded
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize(
    "arch", [a for a in ARCH_IDS
             if "decode_32k" in applicable_shapes(get_config(a))]
)
def test_decode_matches_full_forward(arch, smoke_models):
    """prefill(t[:k]) + decode(t[k:]) must equal forward(t) at each position
    (fp32 state/caches) — validates cache indexing and SSM state carry."""
    cfg, params = smoke_models(arch)
    B, S, k = 2, 12, 8
    key = jax.random.PRNGKey(42)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        patches = jax.random.normal(
            key, (B, cfg.n_patches, cfg.embed_in_dim))
        batch["patches"] = patches
    full_logits, _ = tf.forward(params, cfg, batch)

    state = tf.init_decode_state(cfg, B, S + cfg.n_patches,
                                 cache_dtype=jnp.float32)
    pre = {"tokens": tokens[:, :k]}
    if cfg.family == "vlm":
        pre["patches"] = patches
    logits, state = tf.decode_step(params, cfg, state, pre)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1], np.float32),
        np.asarray(full_logits[:, cfg.n_patches + k - 1
                               if cfg.family == "vlm" else k - 1],
                   np.float32),
        rtol=2e-2, atol=2e-2,
    )
    for i in range(k, S):
        step_batch = {"tokens": tokens[:, i:i + 1]}
        if cfg.family == "vlm":
            step_batch["patches"] = jnp.zeros((B, 0, cfg.embed_in_dim))
        logits, state = tf.decode_step(params, cfg, state, step_batch)
        want = full_logits[:, cfg.n_patches + i
                           if cfg.family == "vlm" else i]
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(want, np.float32),
            rtol=2e-2, atol=2e-2,
        )


def test_unrolled_layers_match_scan():
    cfg = smoke_config("llama3-8b")
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    batch = concrete_batch(cfg, SMALL)
    l1 = tf.loss_fn(params, cfg, batch)
    l2 = tf.loss_fn(params, cfg, batch, unroll_layers=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_logits_chunked_loss_matches_full():
    cfg = smoke_config("gemma-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(2))
    batch = concrete_batch(cfg, SMALL)
    full = tf.loss_fn(params, cfg, batch)
    chunked = tf.loss_fn(params, cfg, batch, logits_chunk=8)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)


def test_vocab_padding_masked():
    cfg = smoke_config("hubert-xlarge")  # vocab 503 -> padded 512
    assert cfg.vocab_padded == 512
    params = tf.init_params(cfg, jax.random.PRNGKey(3))
    batch = concrete_batch(cfg, SMALL)
    logits, _ = tf.forward(params, cfg, batch)
    pad_cols = np.asarray(logits, np.float32)[..., cfg.vocab_size:]
    assert (pad_cols < -1e20).all()


def test_moe_aux_loss_present():
    cfg = smoke_config("granite-moe-1b-a400m")
    params = tf.init_params(cfg, jax.random.PRNGKey(4))
    batch = concrete_batch(cfg, SMALL)
    _, aux = tf.forward(params, cfg, batch)
    assert float(aux) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_brief(arch):
    """Spot-check the exact assigned hyperparameters."""
    cfg = get_config(arch)
    brief = {
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == brief
    moe_brief = {
        "granite-moe-1b-a400m": (32, 8),
        "arctic-480b": (128, 2),
        "jamba-v0.1-52b": (16, 2),
    }
    if arch in moe_brief:
        assert (cfg.moe.n_experts, cfg.moe.top_k) == moe_brief[arch]
    else:
        assert cfg.moe is None
