"""Table 1 reproduction: mean/variance of prediction error (%) for
WordCount and Exim Mainlog parsing.

Paper values (4-node Hadoop, 8 GB): WordCount mean 0.92 / var 2.60;
Exim MainLog mean 2.80 / var 6.70.  Claim validated: mean error < 5%.

Protocol (faithful): profile 20 (M,R) settings in [5,40], 5 repeats each,
mean per experiment; fit Eqn. 6 OLS on the cubic no-cross-term basis;
predict 8 random unseen settings; report |pred-actual|/actual statistics.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import heldout_configs, profile_app
from repro.core import fit, prediction_error_stats


def run(tokens: int = 1 << 16, repeats: int = 5, verbose: bool = False):
    rows = []
    for app_name in ("wordcount", "eximparse"):
        runner, prof = profile_app(
            app_name, tokens=tokens, repeats=repeats, verbose=verbose
        )
        model = fit(prof.params, prof.times)  # paper-faithful OLS
        test = heldout_configs()
        actual = np.array([
            np.mean([runner(c) for _ in range(repeats)]) for c in test
        ])
        stats = prediction_error_stats(model, test, actual)
        rows.append({
            "app": app_name,
            "mean_pct": stats["mean_pct"],
            "var_pct": stats["var_pct"],
            "median_pct": stats["median_pct"],
            "max_pct": stats["max_pct"],
            "train_r2": model.r2,
            "noise_cv_pct": float(prof.repeat_cv().mean() * 100),
        })
    return rows


def main(tokens: int = 1 << 16, repeats: int = 5) -> list[str]:
    rows = run(tokens=tokens, repeats=repeats)
    out = ["table1,app,mean_err_pct,var_err_pct,median_err_pct,"
           "max_err_pct,train_r2,repeat_noise_cv_pct"]
    for r in rows:
        out.append(
            f"table1,{r['app']},{r['mean_pct']:.3f},{r['var_pct']:.3f},"
            f"{r['median_pct']:.3f},{r['max_pct']:.3f},"
            f"{r['train_r2']:.4f},{r['noise_cv_pct']:.2f}"
        )
    out.append(
        "table1_paper_reference,wordcount,0.9204,2.6013,,,,"
    )
    out.append(
        "table1_paper_reference,eximparse,2.7982,6.7008,,,,"
    )
    return out


if __name__ == "__main__":
    print("\n".join(main()))
