"""Benchmark section ``resource``: resource observability's two claims.

* **scheduling** — on a *contended* fabric (``Cluster(...,
  net_capacity=...)``: the contention-aware ground truth fair-share-
  stretches overlapping shuffles), the fabric-window-aware policy
  (``predict-resource``) must beat the resource-blind ``predict-sjf``
  on makespan.  The guarded metric is ``makespan_win`` — blind makespan
  over aware makespan, which must stay > 1 (scheduling against predicted
  fabric demand must *help*) and is gated against the committed value by
  ``run.py --check``.  The aware run is exported as
  ``resource.trace.json`` with the pid 4 "cluster resources" counter
  tracks (fabric bytes/s vs capacity, busy CPU) and the audited
  per-job ``contention`` phases — span tiling must close over them.

* **models** — per-(phase, resource) regressions on the paper's (M, R)
  basis, fit from noisy analytic traces, evaluated on held-out configs
  against the noise-free closed form.  Bands follow the companion
  papers: per-phase CPU-seconds heldout MAE <= ~10% (arXiv:1203.4054
  reports ~9% for total CPU) and the shuffle's on-wire bytes are an
  exact form (``pairs * PAIR_BYTES``, linear in size — arXiv:1206.2016),
  so the bytes model must reproduce it to numerical precision.

Both experiments are closed-form analytic simulations: committed values
and CI re-runs must agree exactly.
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import heldout_configs, training_configs
from repro.cluster import (
    AnalyticOracle,
    Cluster,
    generate_workload,
    get_policy,
)
from repro.obs import ClusterMetrics, ResourceTimeline, SpanRecorder

SEED = 11

# ---- scheduling experiment ------------------------------------------------

SCHED_JOBS = 32
SCHED_WORKERS = 8
#: sustained fabric bytes/s.  A lone shuffle streams ~3.3 MB/s nominal
#: (bytes and wall are both linear in pairs, so the rate is nearly
#: size-free); under this budget a single transfer already stretches and
#: every *overlap* stretches much harder — which is the only thing
#: scheduling can avoid, since fair share conserves bytes.
NET_CAPACITY = 1.5e6
#: shuffle-heavy trace: big inputs arriving in bursts so several
#: shuffles *want* to overlap.
SCHED_SIZES = (1 << 16, 1 << 18)
SCHED_INTERARRIVAL = 0.03

# ---- model experiment -----------------------------------------------------

MODEL_APP = "wordcount"
MODEL_SIZES = (1 << 14, 1 << 15, 1 << 16)
MODEL_WORKERS = 8
MODEL_REPEATS = 3
MODEL_NOISE = 0.03
#: companion-paper band: heldout per-phase CPU-seconds MAE (percent).
CPU_BAND_PCT = 10.0
#: "exact form" tolerance for the bytes model (percent, numerical only).
NET_EXACT_PCT = 0.01


def _policy(name: str):
    kwargs = dict(
        seed=SEED,
        # One grant size so several jobs co-schedule (8 workers / grant 2
        # = 4 concurrent shuffles): the fabric, not the pool, is the
        # bottleneck under test.
        worker_grid=(2,),
        mapper_grid=(4, 8, 16),
        reducer_grid=(4, 8, 16),
        online=False,
    )
    if name == "predict-resource":
        kwargs["net_capacity"] = NET_CAPACITY
    return get_policy(name, **kwargs)


def sched_run(policy_name: str) -> tuple[dict, object, ClusterMetrics]:
    oracle = AnalyticOracle(noise=0.02, seed=SEED)
    jobs = generate_workload(
        SCHED_JOBS, seed=SEED, arrival="bursty",
        mean_interarrival=SCHED_INTERARRIVAL, size_range=SCHED_SIZES,
    )
    metrics = ClusterMetrics()
    cluster = Cluster(
        SCHED_WORKERS, oracle, metrics=metrics, net_capacity=NET_CAPACITY,
    )
    result = cluster.run(jobs, _policy(policy_name))
    m = result.metrics()
    stats = {
        "makespan_s": m["makespan_s"],
        "mean_turnaround_s": m["mean_turnaround_s"],
        "contention_s_total": round(m["contention_s_total"], 4),
        "n_contended_jobs": m["n_contended_jobs"],
        "n_contention_episodes": m["n_contention_episodes"],
    }
    return stats, result, metrics


def export_trace(result, metrics, outdir: str | None) -> dict:
    """Span-check the contended run and export the Chrome trace with
    fabric/CPU counter tracks; returns the export health stats."""
    rec = SpanRecorder()
    rec.record(result)
    violations = rec.check()
    doc = rec.chrome()
    issues = rec.validate()
    timeline = ResourceTimeline.from_result(result)
    summary = timeline.publish(metrics.registry)
    track_names = {
        e["name"] for e in doc["traceEvents"] if e.get("ph") == "C"
    }
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, "resource.trace.json"), "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
    return {
        "tiling_violations": len(violations),
        "chrome_issues": len(issues),
        "n_trace_events": len(doc["traceEvents"]),
        "has_fabric_tracks": {"fabric_bytes_per_s", "fabric_capacity",
                              "busy_cpu"} <= track_names,
        "net_peak_utilization": round(
            summary.get("net_peak_utilization", 0.0), 4
        ),
        "n_over_capacity_episodes": summary["n_over_capacity_episodes"],
    }


def _collect(oracle, configs, job_ids) -> tuple[np.ndarray, list]:
    """(params, traces_per_config) over the (M, R) x size grid."""
    params, traces = [], []
    for m, r in configs:
        for size in MODEL_SIZES:
            reps = []
            for j in job_ids:
                oracle.time(
                    MODEL_APP, "jnp", size, int(m), int(r),
                    MODEL_WORKERS, job_id=j,
                )
                reps.append(oracle.take_trace())
            params.append((float(m), float(r), float(size) / 1024.0))
            traces.append(reps)
    return np.asarray(params, dtype=np.float64), traces


def run_models() -> dict:
    from repro.telemetry.models import (
        TIME_RESOURCE,
        fit_phase_models,
        targets_from_traces,
    )

    fit_kwargs = dict(degree=2, cross_terms=True, scale=True, lam=1e-8)
    train_p, train_t = _collect(
        AnalyticOracle(noise=MODEL_NOISE, seed=SEED),
        training_configs(), job_ids=range(MODEL_REPEATS),
    )
    models = fit_phase_models(
        train_p, targets_from_traces(train_t), **fit_kwargs
    )
    # Heldout ground truth: the noise-free closed form on unseen configs.
    held_p, held_t = _collect(
        AnalyticOracle(noise=0.0, seed=SEED), heldout_configs(),
        job_ids=(0,),
    )
    truth = targets_from_traces(held_t)

    def mae_pct(phase: str, resource: str) -> float:
        pred = models.predict(phase, resource, held_p)
        true = truth[(phase, resource)]
        return float(np.mean(np.abs(pred - true) / np.abs(true)) * 100.0)

    cpu = {p: round(mae_pct(p, "cpu_s"), 3)
           for p in ("map", "shuffle", "reduce")}
    cpu_mae = round(float(np.mean(list(cpu.values()))), 3)
    net_mae = round(mae_pct("shuffle", "net_bytes"), 6)
    time_mae = round(float(np.mean(
        [mae_pct(p, TIME_RESOURCE) for p in ("map", "shuffle", "reduce")]
    )), 3)
    return {
        "n_train": int(train_p.shape[0]),
        "n_heldout": int(held_p.shape[0]),
        "cpu_mae_pct_per_phase": cpu,
        "cpu_mae_pct": cpu_mae,
        "cpu_band_pct": CPU_BAND_PCT,
        "cpu_within_band": cpu_mae <= CPU_BAND_PCT,
        "net_mae_pct": net_mae,
        "net_exact_form": net_mae <= NET_EXACT_PCT,
        "time_mae_pct": time_mae,
    }


def main(
    tokens: int, repeats: int, outdir: str | None = None
) -> tuple[list[str], dict]:
    """Section entry point.  ``tokens`` / ``repeats`` are unused: both
    experiments are closed-form analytic simulations whose *values* are
    the artifact — the committed baseline and every CI re-run must agree
    exactly, so nothing here may scale with harness knobs."""
    del tokens, repeats
    blind, _, _ = sched_run("predict-sjf")
    aware, aware_result, aware_metrics = sched_run("predict-resource")
    makespan_win = blind["makespan_s"] / max(aware["makespan_s"], 1e-9)
    trace = export_trace(aware_result, aware_metrics, outdir)
    model = run_models()

    rows = [
        "resource,experiment,metric,value",
        *(f"resource,sched_blind,{k},{v}" for k, v in sorted(blind.items())),
        *(f"resource,sched_aware,{k},{v}" for k, v in sorted(aware.items())),
        f"resource,sched,makespan_win,{makespan_win:.3f}",
        *(f"resource,trace,{k},{v}" for k, v in sorted(trace.items())),
        *(
            f"resource,models,{k},{v}"
            for k, v in sorted(model.items())
            if not isinstance(v, dict)
        ),
    ]
    summary = {
        "scheduling": {
            "net_capacity": NET_CAPACITY,
            "n_jobs": SCHED_JOBS,
            "workers": SCHED_WORKERS,
            "blind": blind,
            "aware": aware,
            # Guarded (higher-better) by run.py --check: scheduling
            # against predicted fabric windows must keep beating blind
            # SJF on the contended trace.
            "makespan_win": round(makespan_win, 3),
            "aware_wins": makespan_win > 1.0,
        },
        "trace": trace,
        # cpu_mae_pct / net_mae_pct are guarded (lower-better).
        "models": model,
    }
    return rows, summary
