"""Benchmark section ``elastic``: regrant-aware vs admission-only policies.

Two deterministic traces on the :class:`~repro.elastic.sim.ElasticCluster`
(every policy on the same simulator, so overhead accounting and event
granularity are identical):

* **contended** — bursty arrivals, tight deadline slack, an undersized
  pool: deadline jobs routinely arrive while long best-effort jobs hold
  the workers.  ``predict-elastic`` shrinks those victims at wave
  boundaries to backfill the deadline jobs; the claim under test is
  *strictly better deadline attainment* than ``predict-deadline``.
* **uncontended** — light poisson load, generous slack: the elastic
  moves never trigger, and the claim is *no makespan regression* (the
  schedules must in fact be identical, regrant count zero).

``predict-sjf`` rides along as the throughput-oriented reference.
"""

from __future__ import annotations

from repro.cluster import (
    AnalyticOracle,
    assign_deadlines,
    generate_workload,
    get_policy,
)
from repro.elastic import ElasticCluster

N_JOBS = 50
WORKERS = 12
POLICIES = ("predict-sjf", "predict-deadline", "predict-elastic")

#: trace recipes; sizes scale with the harness --tokens knob.
CONTENDED = dict(arrival="bursty", mean_interarrival=0.08,
                 deadline_fraction=0.5, slack_range=(1.1, 2.2))
UNCONTENDED = dict(arrival="poisson", mean_interarrival=1.0,
                   deadline_fraction=0.5, slack_range=(2.5, 6.0))


def run_trace(
    recipe: dict,
    *,
    n_jobs: int = N_JOBS,
    workers: int = WORKERS,
    size_range: tuple[int, int] = (1 << 14, 1 << 18),
    noise: float = 0.02,
    seed: int = 1,
    policies=POLICIES,
) -> dict[str, dict]:
    """Each policy over one shared trace on the elastic simulator."""
    out = {}
    for name in policies:
        # Fresh oracle per policy: noise streams are deterministic per
        # (job, config), so every policy sees identical true times.
        oracle = AnalyticOracle(noise=noise, seed=seed)
        jobs = generate_workload(
            n_jobs, seed=seed, arrival=recipe["arrival"],
            mean_interarrival=recipe["mean_interarrival"],
            size_range=size_range,
        )
        jobs = assign_deadlines(
            jobs, lambda j: oracle.nominal_time(j.app, j.size),
            slack_range=recipe["slack_range"],
            fraction=recipe["deadline_fraction"], seed=seed + 1,
        )
        cluster = ElasticCluster(workers, oracle)
        policy = get_policy(name, seed=seed)
        m = cluster.run(jobs, policy).metrics()
        m["n_shrinks"] = getattr(policy, "n_shrinks", 0)
        m["n_grows"] = getattr(policy, "n_grows", 0)
        out[name] = m
    return out


def main(tokens: int, repeats: int) -> tuple[list[str], dict]:
    """Section entry point.  ``tokens`` only ever *raises* the max job
    size: the closed-form simulation costs the same at any size, and
    shrinking the heavy tail would wash out the contention the section
    exists to measure.  ``repeats`` is unused — the shared deterministic
    trace is the comparison."""
    del repeats
    size_hi = max(1 << 18, tokens)
    size_range = (1 << 14, size_hi)
    contended = run_trace(CONTENDED, size_range=size_range)
    uncontended = run_trace(UNCONTENDED, size_range=size_range)

    rows = [
        "elastic,trace,policy,makespan_s,slo_attainment,n_rejected,"
        "n_regrants,n_shrinks,n_grows,regrant_overhead_s,utilization"
    ]

    def fmt(x, nd=3):
        return "" if x is None else f"{x:.{nd}f}"

    for trace_name, metrics in (
        ("contended", contended), ("uncontended", uncontended)
    ):
        for name, m in metrics.items():
            rows.append(
                f"elastic,{trace_name},{name},{fmt(m['makespan_s'])},"
                f"{fmt(m['slo_attainment'])},{m['n_rejected']},"
                f"{m['n_regrants']},{m['n_shrinks']},{m['n_grows']},"
                f"{fmt(m['regrant_overhead_s'])},{fmt(m['utilization'])}"
            )

    slo_elastic = contended["predict-elastic"]["slo_attainment"]
    slo_deadline = contended["predict-deadline"]["slo_attainment"]
    mk_elastic = uncontended["predict-elastic"]["makespan_s"]
    mk_deadline = uncontended["predict-deadline"]["makespan_s"]
    summary = {
        "n_jobs": N_JOBS,
        "workers": WORKERS,
        "contended": contended,
        "uncontended": uncontended,
        # The two acceptance claims of the elastic layer:
        "elastic_vs_deadline": {
            "contended_slo_elastic": slo_elastic,
            "contended_slo_deadline": slo_deadline,
            "strictly_better_slo": slo_elastic > slo_deadline,
            "uncontended_makespan_elastic_s": mk_elastic,
            "uncontended_makespan_deadline_s": mk_deadline,
            "no_makespan_regression": mk_elastic <= mk_deadline * 1.001,
            "uncontended_regrants": (
                uncontended["predict-elastic"]["n_regrants"]
            ),
        },
    }
    rows.append(
        "elastic,_summary,"
        f"slo={slo_elastic:.3f}_vs_{slo_deadline:.3f},"
        f"strictly_better={summary['elastic_vs_deadline']['strictly_better_slo']},"
        f"no_makespan_regression="
        f"{summary['elastic_vs_deadline']['no_makespan_regression']},"
        f"contended_regrants={contended['predict-elastic']['n_regrants']}"
    )
    return rows, summary
