"""Benchmark section ``obs``: the observability layer's two claims.

* **spans** — a contended elastic trace (regrants + suspend-to-disk) is
  recorded by :class:`repro.obs.SpanRecorder` and exported as a Chrome
  trace-event file.  The claims under test: the span tree *tiles* every
  job's turnaround exactly (wait + execution segments + regrant/suspend
  gaps sum to finish - arrival, zero violations), and the exported JSON
  is well-formed (``validate_chrome_trace`` returns no issues).  The
  run's ``run.trace.json`` / ``metrics.json`` land next to the
  ``BENCH_*.json`` artifacts, so CI uploads an openable trace per build,
  and the streaming p50/p99 service quantiles are deterministic —
  committed and re-derived values must match bit-for-bit.

* **drift** — an :class:`~repro.cluster.oracle.AnalyticOracle` platform
  shift (every job from ``SHIFT_AT`` on runs ``SHIFT_FACTOR`` x slower;
  the bootstrap profiling that built the models never saw it) is run
  against ``predict-sjf`` twice: the every-completion refit baseline,
  whose seed-anchored refits cannot dig the model out from under its
  stale profiling rows, and the drift-aware variant whose
  :class:`~repro.obs.PredictionLedger` alarms trigger category-targeted
  ``refit_category`` corrections.  The guarded metric is ``recovery``:
  baseline tail MAE over drift-aware tail MAE, which must stay > 1 (the
  alarms must *help*) and is gated against the committed value by
  ``run.py --check``.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.cluster import (
    AnalyticOracle,
    Cluster,
    assign_deadlines,
    generate_workload,
    get_policy,
)
from repro.elastic import ElasticCluster
from repro.obs import ClusterMetrics, PredictionLedger, SpanRecorder

SEED = 7

# ---- spans experiment -----------------------------------------------------

SPAN_JOBS = 30
SPAN_WORKERS = 8

# ---- drift experiment -----------------------------------------------------

DRIFT_JOBS = 150
DRIFT_WORKERS = 12
SHIFT_AT = 50          #: first shifted job_id (mid-trace platform change)
SHIFT_FACTOR = 2.0     #: post-shift slowdown the models never profiled


def run_spans(outdir: str | None) -> dict:
    """Contended elastic trace -> span tree -> Chrome export + metrics."""
    oracle = AnalyticOracle(noise=0.02, seed=SEED)
    jobs = generate_workload(
        SPAN_JOBS, seed=SEED, arrival="bursty", mean_interarrival=0.08,
        size_range=(1 << 14, 1 << 18),
    )
    jobs = assign_deadlines(
        jobs, lambda j: oracle.nominal_time(j.app, j.size),
        slack_range=(1.1, 2.2), fraction=0.5, seed=SEED + 1,
    )
    metrics = ClusterMetrics()
    cluster = ElasticCluster(
        SPAN_WORKERS, oracle, snapshot_overhead_s=0.02,
        restore_overhead_s=0.02, metrics=metrics,
    )
    policy = get_policy("predict-elastic", seed=SEED, suspend=True)
    result = cluster.run(jobs, policy)

    rec = SpanRecorder()
    rec.record(result)
    violations = rec.check()
    doc = rec.chrome()
    issues = rec.validate()
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, "run.trace.json"), "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        metrics.save(os.path.join(outdir, "metrics.json"))
    m = result.metrics()
    s = metrics.summary()
    return {
        "n_jobs": SPAN_JOBS,
        "workers": SPAN_WORKERS,
        "makespan_s": m["makespan_s"],
        "n_regrants": m["n_regrants"],
        "n_suspends": int(s["n_suspends"]),
        "n_spans": sum(1 for root in rec.roots for _ in root.walk()),
        "n_trace_events": len(doc["traceEvents"]),
        "tiling_violations": len(violations),
        "chrome_issues": len(issues),
        "p50_turnaround_s": s["p50_turnaround_s"],
        "p99_turnaround_s": s["p99_turnaround_s"],
        "p50_wait_s": s["p50_wait_s"],
        "p99_wait_s": s["p99_wait_s"],
    }


def _post_shift_mae(result) -> tuple[float, float]:
    """(post-shift MAE%, tail-third MAE%) by completion order."""
    recs = sorted(
        (r for r in result.records if r.finish is not None),
        key=lambda r: r.finish,
    )
    errs = [
        abs(r.plan.predicted_time - r.true_time) / r.true_time * 100.0
        for r in recs
        if r.spec.job_id >= SHIFT_AT and r.plan is not None
        and r.plan.predicted_time and r.true_time
    ]
    tail = errs[-len(errs) // 3:]
    return float(np.mean(errs)), float(np.mean(tail))


def run_drift(drift_aware: bool) -> dict:
    oracle = AnalyticOracle(
        noise=0.02, seed=SEED, shift_after_job=SHIFT_AT,
        shift_factor=SHIFT_FACTOR,
    )
    jobs = generate_workload(
        DRIFT_JOBS, seed=SEED, mean_interarrival=0.4,
        size_range=(1 << 14, 1 << 17),
    )
    ledger = PredictionLedger() if drift_aware else None
    policy = get_policy("predict-sjf", seed=SEED, ledger=ledger)
    result = Cluster(DRIFT_WORKERS, oracle).run(jobs, policy)
    post_mae, tail_mae = _post_shift_mae(result)
    return {
        "post_shift_mae_pct": round(post_mae, 2),
        "tail_mae_pct": round(tail_mae, 2),
        "alarms": getattr(policy, "n_drift_alarms", 0),
        "drift_refits": policy.refiner.n_drift_refits if policy.refiner
        else 0,
        "outlier_samples": ledger.n_outliers if ledger else 0,
    }


def main(
    tokens: int, repeats: int, outdir: str | None = None
) -> tuple[list[str], dict]:
    """Section entry point.  ``tokens`` / ``repeats`` are unused: both
    experiments are closed-form analytic simulations whose *values* are
    the artifact — the committed baseline and every CI re-run must agree
    exactly, so nothing here may scale with harness knobs."""
    del tokens, repeats
    spans = run_spans(outdir)
    base = run_drift(drift_aware=False)
    aware = run_drift(drift_aware=True)
    recovery = base["tail_mae_pct"] / max(aware["tail_mae_pct"], 1e-9)

    rows = [
        "obs,experiment,metric,value",
        *(f"obs,spans,{k},{v}" for k, v in sorted(spans.items())),
        *(f"obs,drift_baseline,{k},{v}" for k, v in sorted(base.items())),
        *(f"obs,drift_aware,{k},{v}" for k, v in sorted(aware.items())),
        f"obs,drift,recovery,{recovery:.3f}",
    ]
    summary = {
        "spans": spans,
        "drift": {
            "shift_at": SHIFT_AT,
            "shift_factor": SHIFT_FACTOR,
            "baseline": base,
            "drift_aware": aware,
            # Guarded (higher-better) by run.py --check: alarm-triggered
            # refits must keep beating the every-completion baseline.
            "recovery": round(recovery, 3),
            "alarms_help": recovery > 1.0,
        },
    }
    return rows, summary
