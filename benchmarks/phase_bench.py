"""Benchmark section ``phases``: per-phase telemetry + decomposed models.

The paper's Table 1 reports total-time prediction error; this section
decomposes it.  For WordCount and Exim parse on the Fig. 3 grid
(20 (M, R) settings in [5, 40]^2):

1. every setting runs through the telemetry path (``build_job(recorder=)``)
   and yields per-phase wall times + resource counters;
2. one regression per (phase, resource) is fitted on the paper's basis
   (``repro.telemetry.models``) next to the monolithic total-time model;
3. prediction error is reported per phase and for the *composed* predictor
   (sum of phase models) vs the monolithic one, on the training grid and
   on held-out settings — OLS is linear in its target, so composed can
   never lose on a shared basis, and the gap is verified numerically;
4. counter conservation (shuffle bytes in == out + dropped, phase times
   sum ~ total) is checked across all three reduce backends;
5. XLA's static flops/bytes estimates per phase (``telemetry.estimator``)
   are reported next to the measured times when the backend provides them.

CSV rows:
  phases,<app>,<M>,<R>,<phase>,<mean_time_s>,<share_pct>
  phases,<app>,_model,<phase>,train_mape_pct,
  phases,<app>,_composed,<grid|heldout>,composed_mape,monolithic_mape
  phases,<app>,_conservation,<backend>,ok,
  phases,<app>,_xla,<phase>,<flops>,<bytes>
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import heldout_configs, make_app, training_configs
from repro.core import fit
from repro.mapreduce import REDUCE_BACKENDS, JobConfig, build_job
from repro.telemetry import (
    PhaseRecorder,
    collect_traced,
    composed_vs_monolithic,
    estimates_available,
    fit_phase_models,
    stage_cost_estimates,
    targets_from_traces,
)
from repro.telemetry.models import TIME_RESOURCE

#: the conservation cross-check runs every reduce backend; the Pallas
#: kernel builds a (C, C) one-hot per partition, so keep its corpus tiny.
CONSERVATION_TOKENS = 1 << 12


class TracedRunner:
    """Compile-cached traced runs: trace(config) for one application."""

    def __init__(self, app, corpus, *, warmup: int = 1, **cfg_kwargs):
        self.app = app
        self.corpus = corpus
        self.warmup = warmup
        self.cfg_kwargs = cfg_kwargs
        self.recorder = PhaseRecorder()
        self._cache: dict = {}

    def __call__(self, config):
        """Run once; return the JobTrace (collect phase included)."""
        M, R = int(round(config[0])), int(round(config[1]))
        key = (M, R)
        if key not in self._cache:
            job = build_job(
                self.app,
                JobConfig(num_mappers=M, num_reducers=R, **self.cfg_kwargs),
                len(self.corpus),
                recorder=self.recorder,
            )
            for _ in range(self.warmup):
                job(self.corpus)
                self.recorder.traces.pop()  # warmup (compile) not telemetry
            self._cache[key] = job
        job = self._cache[key]
        out_keys, out_vals, _ = job(self.corpus)
        trace = self.recorder.last
        collect_traced(trace, out_keys, out_vals)
        return trace


def profile_phases(runner, configs, repeats: int):
    """(params, traces_per_config): ``repeats`` traces per setting."""
    traces = [[runner(row) for _ in range(repeats)] for row in configs]
    return np.asarray(configs, dtype=np.float64), traces


def conservation_rows(app_name: str, app_factory, corpus) -> tuple[list, bool]:
    """Run one mid-grid config per reduce backend; verify conservation and
    counter equality (counters are semantics, never a backend axis)."""
    rows, ok = [], True
    reference = None
    for name in sorted(REDUCE_BACKENDS):
        runner = TracedRunner(
            app_factory, corpus, capacity_factor=8.0, reduce_backend=name
        )
        trace = runner((8, 8))
        violations = trace.check_conservation()
        # cpu_s / net_s are clock measurements — deterministic-equality
        # across backends applies to the semantic counters only.
        counters = {
            p.phase: {k: v for k, v in p.counters.items()
                      if k not in ("cpu_s", "net_s")}
            for p in trace.phases
        }
        if reference is None:
            reference = counters
        backend_ok = not violations and counters == reference
        ok = ok and backend_ok
        rows.append(
            f"phases,{app_name},_conservation,{name},"
            f"{'ok' if backend_ok else 'VIOLATION:' + ';'.join(violations)},"
        )
    return rows, ok


def main(tokens: int, repeats: int = 3) -> tuple[list[str], dict]:
    repeats = max(2, repeats)
    rows = ["phases,app,mappers,reducers,phase,mean_time_s,share_pct"]
    summary: dict = {"apps": {}}
    all_composed_le = True
    all_conservation = True
    for app_name in ("wordcount", "eximparse"):
        app, corpus = make_app(app_name, tokens)
        runner = TracedRunner(app, corpus, capacity_factor=8.0)
        train = training_configs()
        params, traces = profile_phases(runner, train, repeats)
        targets = targets_from_traces(traces)
        phase_names = traces[0][0].phase_names()
        phase_times = {
            p: targets[(p, TIME_RESOURCE)] for p in phase_names
        }
        totals = np.sum(list(phase_times.values()), axis=0)

        # Per-config rows: where does the time go at each setting?
        for i, (m, r) in enumerate(params):
            for p in phase_names:
                t = phase_times[p][i]
                rows.append(
                    f"phases,{app_name},{int(m)},{int(r)},{p},"
                    f"{t:.5f},{t / totals[i] * 100:.1f}"
                )

        # Decomposed models (paper basis) + the monolithic reference.
        phase_models = fit_phase_models(params, targets)
        monolithic = fit(params, totals)
        for p in phase_names:
            mape = phase_models.model(p).train_mape
            rows.append(f"phases,{app_name},_model,{p},{mape:.3f},")

        grid_cmp = composed_vs_monolithic(
            phase_models, monolithic, params, totals
        )
        rows.append(
            f"phases,{app_name},_composed,grid,"
            f"{grid_cmp['composed_mean_pct']:.4f},"
            f"{grid_cmp['monolithic_mean_pct']:.4f}"
        )
        # Held-out settings (paper's prediction phase), measured fresh.
        held = heldout_configs()
        h_params, h_traces = profile_phases(runner, held, repeats)
        h_targets = targets_from_traces(h_traces)
        h_totals = np.sum(
            [h_targets[(p, TIME_RESOURCE)] for p in phase_names], axis=0
        )
        held_cmp = composed_vs_monolithic(
            phase_models, monolithic, h_params, h_totals
        )
        rows.append(
            f"phases,{app_name},_composed,heldout,"
            f"{held_cmp['composed_mean_pct']:.4f},"
            f"{held_cmp['monolithic_mean_pct']:.4f}"
        )
        all_composed_le = all_composed_le and grid_cmp["composed_le_monolithic"]

        # Conservation across every reduce backend (small corpus: pallas).
        cons_app, cons_corpus = make_app(
            app_name, min(tokens, CONSERVATION_TOKENS)
        )
        cons_rows, cons_ok = conservation_rows(
            app_name, cons_app, cons_corpus
        )
        rows += cons_rows
        all_conservation = all_conservation and cons_ok

        # Static XLA cost estimates for a mid-grid setting.
        estimates = stage_cost_estimates(
            app, JobConfig(num_mappers=16, num_reducers=16,
                           capacity_factor=8.0), len(corpus)
        )
        for p, est in estimates.items():
            rows.append(
                f"phases,{app_name},_xla,{p},{est['flops']:.0f},"
                f"{est['bytes']:.0f}"
            )

        shuffle_bytes_model = phase_models.model("shuffle", "bytes_out")
        summary["apps"][app_name] = {
            "phase_time_share_pct": {
                p: float(phase_times[p].sum() / totals.sum() * 100)
                for p in phase_names
            },
            "per_phase_train_mape_pct": {
                p: phase_models.model(p).train_mape for p in phase_names
            },
            "composed_vs_monolithic_grid": grid_cmp,
            "composed_vs_monolithic_heldout": held_cmp,
            "shuffle_bytes_model_mape_pct": shuffle_bytes_model.train_mape,
            "conservation_ok": cons_ok,
            "xla_estimates": estimates,
            "xla_estimates_available": estimates_available(estimates),
        }

    summary["composed_le_monolithic_all_apps"] = all_composed_le
    summary["conservation_ok_all"] = all_conservation
    rows.append(
        f"phases,_summary,composed_le_monolithic={all_composed_le},"
        f"conservation_ok={all_conservation},,"
    )
    return rows, summary


if __name__ == "__main__":
    out, _ = main(1 << 14, 2)
    print("\n".join(out))
