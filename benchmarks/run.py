"""Benchmark harness: one section per paper table/figure + beyond-paper.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--tokens N]``

Sections (CSV rows on stdout):
  table1  — Table 1: mean/var prediction error, WordCount + EximParse
  fig3    — Fig. 3: per-experiment predicted vs actual time
  fig4    — Fig. 4: execution-time surface over (M, R) + observed optimum
  tuner   — beyond-paper: regression autotuner vs exhaustive search
  backends— beyond-paper: reduce-backend (jnp/pallas/xla) timing comparison
  phases  — beyond-paper: per-phase telemetry, composed-vs-monolithic models
  cluster — beyond-paper: predictive multi-job scheduling vs FIFO baseline
  elastic — beyond-paper: preemptive regrant scheduling vs admission-only
  pipeline— beyond-paper: pipelined-vs-fused engine speedup + depth-axis MAE
  obs     — beyond-paper: span-tiling validation + drift-alarm-triggered
            refits recovering prediction MAE after a mid-trace platform
            shift (also lands run.trace.json / metrics.json artifacts)
  service — beyond-paper: flash-crowd service stream; burn-rate overload
            control must strictly beat a static admission cap on both
            p99 turnaround and SLO-good goodput (also lands
            service.trace.json / service.prom artifacts)
  combine — beyond-paper: map-side combining — live-engine shuffle-byte
            contraction on skewed WordCount (bit-exactness asserted
            in-bench), contended-fabric makespan win from opening the
            combiner axis, heldout combined-bytes model error (also
            lands combine.trace.json)
  roofline— §Roofline table from the dry-run artifacts
  kernels — per-kernel microbench (us/call, interpret mode)

Every section also lands machine-readable artifacts in ``--outdir``
(default ``experiments/bench/``): ``bench_<section>.csv`` with the
section's rows and ``BENCH_<section>.json`` with summary stats (row count,
wall time, status, any section-provided summary dict, and a provenance
stamp — git SHA, jax version, platform — so ``experiments/bench/``
trajectories are comparable across PRs).

``--check`` turns the committed artifacts into a regression gate: the
fresh summaries are compared against the committed ``BENCH_<sec>.json``
baselines (read before this run overwrites them) and the harness exits
non-zero when any guarded metric — scheduler makespan or SLO attainment,
both from deterministic analytic simulations — regresses by more than
25%.  CI's bench-smoke job runs with ``--check``.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ALL_SECTIONS = (
    "table1", "fig3", "fig4", "tuner", "backends", "phases", "cluster",
    "elastic", "pipeline", "obs", "service", "resource", "combine",
    "roofline", "kernels",
)


def provenance() -> dict:
    """Who/what produced this artifact: git SHA, jax version, platform."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001 - provenance must never kill a bench
        sha = "unknown"
    try:
        import jax

        jax_version = jax.__version__
        backend = jax.default_backend()
    except Exception:  # noqa: BLE001
        jax_version = backend = "unknown"
    import platform as _platform

    return {
        "git_sha": sha,
        "jax_version": jax_version,
        "jax_backend": backend,
        "python_version": _platform.python_version(),
        "platform": _platform.platform(),
    }


def _kernel_micro() -> list[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.flash_attention import attention_ref, flash_attention
    from repro.kernels.segment_reduce import segment_reduce

    rows = ["kernel,name,us_per_call,derived"]
    rng = np.random.default_rng(0)

    def timeit(fn, *args, reps=3):
        fn(*args)  # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / reps * 1e6

    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    us_ref = timeit(lambda a, b, c: attention_ref(a, b, c, causal=True),
                    q, k, v)
    rows.append(f"kernel,attention_ref_256,{us_ref:.0f},xla_reference")
    us_pl = timeit(
        lambda a, b, c: flash_attention(a, b, c, causal=True), q, k, v
    )
    rows.append(
        f"kernel,flash_attention_256,{us_pl:.0f},"
        "interpret_mode_NOT_tpu_timing"
    )
    keys = jnp.asarray(
        np.sort(rng.integers(0, 50, size=(8, 128)).astype(np.int32), axis=1))
    vals = jnp.asarray(rng.integers(0, 9, size=(8, 128)).astype(np.int32))
    us_seg = timeit(segment_reduce, keys, vals)
    rows.append(
        f"kernel,segment_reduce_8x128,{us_seg:.0f},"
        "interpret_mode_NOT_tpu_timing"
    )
    return rows


def run_section(sec: str, tokens: int, repeats: int, outdir: str = ""):
    """Dispatch one section; returns (rows, summary_dict_or_None)."""
    if sec == "table1":
        from benchmarks import table1_prediction_error
        return table1_prediction_error.main(tokens, repeats), None
    if sec == "fig3":
        from benchmarks import fig3_accuracy
        return fig3_accuracy.main(tokens, max(2, repeats - 2)), None
    if sec == "fig4":
        from benchmarks import fig4_surface
        return fig4_surface.main(tokens, max(2, repeats - 2)), None
    if sec == "tuner":
        from benchmarks import tuner_vs_exhaustive
        return tuner_vs_exhaustive.main(tokens), None
    if sec == "backends":
        from benchmarks import backends_compare
        return backends_compare.main(tokens, max(2, repeats - 2)), None
    if sec == "phases":
        from benchmarks import phase_bench
        return phase_bench.main(tokens, max(2, repeats - 2))
    if sec == "cluster":
        from benchmarks import cluster_bench
        return cluster_bench.main(tokens, repeats)
    if sec == "elastic":
        from benchmarks import elastic_bench
        return elastic_bench.main(tokens, repeats)
    if sec == "pipeline":
        from benchmarks import pipeline_bench
        return pipeline_bench.main(tokens, repeats)
    if sec == "obs":
        from benchmarks import obs_bench
        return obs_bench.main(tokens, repeats, outdir=outdir or None)
    if sec == "service":
        from benchmarks import service_bench
        return service_bench.main(tokens, repeats, outdir=outdir or None)
    if sec == "resource":
        from benchmarks import resource_bench
        return resource_bench.main(tokens, repeats, outdir=outdir or None)
    if sec == "combine":
        from benchmarks import combine_bench
        return combine_bench.main(tokens, repeats, outdir=outdir or None)
    if sec == "roofline":
        from benchmarks import roofline
        return roofline.main(), None
    if sec == "kernels":
        return _kernel_micro(), None
    raise ValueError(f"unknown section {sec!r}; expected {ALL_SECTIONS}")


#: --check regression gate: relative tolerance on the guarded metrics.
CHECK_TOLERANCE = 0.25


def _walk_metrics(summary, path=""):
    """Yield (dotted_path, key, value) for every guarded metric leaf."""
    if isinstance(summary, dict):
        for k, v in summary.items():
            p = f"{path}.{k}" if path else str(k)
            if k in (
                "makespan_s", "slo_attainment", "speedup", "recovery",
                "p99_turnaround_s", "goodput", "makespan_win",
                "cpu_mae_pct", "net_mae_pct", "net_reduction",
                "contended_win", "combined_net_mae_pct",
            ) and isinstance(v, (int, float)):
                yield p, k, float(v)
            else:
                yield from _walk_metrics(v, p)


def load_committed(outdir: str, sections) -> tuple[dict, list[str]]:
    """The BENCH_<sec>.json summaries as committed, read *before* this
    run overwrites them — the baseline the --check gate compares against.

    Returns ``(committed, malformed)``: a baseline file that exists but
    does not parse as a JSON object (truncated commit, merge damage) must
    not crash the gate with a raw traceback, nor silently pass as if no
    baseline existed — it is reported as ``_check_warn,malformed_baseline``
    and excluded from comparison, same exit behavior as a missing one.
    """
    committed: dict = {}
    malformed: list[str] = []
    for sec in sections:
        path = os.path.join(outdir, f"BENCH_{sec}.json")
        try:
            with open(path) as f:
                doc = json.load(f)
        except OSError:
            continue
        except (json.JSONDecodeError, UnicodeDecodeError, ValueError):
            malformed.append(sec)
            continue
        if not isinstance(doc, dict):
            malformed.append(sec)
            continue
        committed[sec] = doc
    return committed, malformed


def check_regressions(committed: dict, fresh: dict) -> list[str]:
    """Compare guarded metrics (makespan_s / slo_attainment / speedup) of
    each fresh section summary against the committed baseline.

    A regression is a makespan (or the service section's p99 turnaround,
    or the resource section's heldout CPU/net model error) more than
    ``CHECK_TOLERANCE`` above the committed value, or an SLO
    attainment (or pipelined-mode speedup, the obs section's
    drift-recovery ratio, the service section's SLO-good goodput, or the
    resource section's blind-over-aware makespan win) more than
    ``CHECK_TOLERANCE`` below it.  Only metric paths present in
    both summaries compare; the guarded sections (cluster, elastic) are
    deterministic analytic simulations, so drift means a real behavior
    change, not noise — the pipeline section's speedup is measured
    wall-clock, which is why its tolerance band is the same generous 25%.
    """
    problems: list[str] = []
    for sec, old in committed.items():
        new = fresh.get(sec)
        if new is None or old.get("status") != "ok":
            continue
        if new.get("status") != "ok":
            problems.append(f"{sec}: section now fails "
                            f"({new.get('error', 'unknown error')})")
            continue
        old_metrics = {p: (k, v) for p, k, v in
                       _walk_metrics(old.get("summary", {}))}
        new_metrics = {p: (k, v) for p, k, v in
                       _walk_metrics(new.get("summary", {}))}
        for p, (kind, old_v) in sorted(old_metrics.items()):
            if p not in new_metrics:
                continue
            new_v = new_metrics[p][1]
            if kind in (
                "makespan_s", "p99_turnaround_s", "cpu_mae_pct",
                "net_mae_pct", "net_reduction", "combined_net_mae_pct",
            ) and (
                new_v > old_v * (1 + CHECK_TOLERANCE)
            ):
                problems.append(
                    f"{sec}: {p} regressed {old_v:.3f} -> {new_v:.3f} "
                    f"(+{(new_v / max(old_v, 1e-12) - 1) * 100:.0f}%)"
                )
            elif kind in (
                "slo_attainment", "speedup", "recovery", "goodput",
                "makespan_win", "contended_win",
            ) and new_v < old_v * (1 - CHECK_TOLERANCE):
                problems.append(
                    f"{sec}: {p} regressed {old_v:.3f} -> {new_v:.3f} "
                    f"(-{(1 - new_v / max(old_v, 1e-12)) * 100:.0f}%)"
                )
    return problems


def write_artifacts(
    outdir: str, sec: str, rows: list[str], summary: dict
) -> None:
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, f"bench_{sec}.csv"), "w") as f:
        f.write("\n".join(rows) + ("\n" if rows else ""))
    path = os.path.join(outdir, f"BENCH_{sec}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller corpora / fewer repeats")
    ap.add_argument("--tokens", type=int, default=None)
    ap.add_argument("--sections", default="all",
                    help="comma list: " + ",".join(ALL_SECTIONS))
    ap.add_argument("--outdir", default="experiments/bench",
                    help="where bench_<sec>.csv + BENCH_<sec>.json land "
                         "(empty string disables)")
    ap.add_argument("--check", action="store_true",
                    help="bench-regression guard: compare the fresh "
                         "summaries against the committed BENCH_<sec>.json "
                         "baselines and exit non-zero on a >25%% makespan "
                         "or SLO-attainment regression (CI smoke gate)")
    ap.add_argument("--log-level", default="info",
                    choices=("debug", "info", "warning", "error"))
    ap.add_argument("--log-json", action="store_true",
                    help="section progress on stderr as JSON lines "
                         "instead of text (CSV rows stay on stdout)")
    args = ap.parse_args()
    from repro.obs import get_logger

    log = get_logger(
        "bench", level=args.log_level, json_lines=args.log_json
    )
    tokens = args.tokens or (1 << 14 if args.quick else 1 << 16)
    repeats = 2 if args.quick else 5
    sections = (
        list(ALL_SECTIONS) if args.sections == "all"
        else args.sections.split(",")
    )
    rows: list[str] = []
    t_start = time.time()
    stamp = provenance()
    committed, malformed = (
        load_committed(args.outdir, sections)
        if args.check and args.outdir else ({}, [])
    )
    fresh: dict[str, dict] = {}
    for sec in sections:
        t0 = time.time()
        log.info("section_start", section=sec, msg=f"running {sec}...")
        sec_rows: list[str] = []
        summary: dict = {
            "section": sec,
            "quick": args.quick,
            "tokens": tokens,
            "status": "ok",
            "provenance": stamp,
        }
        try:
            sec_rows, sec_summary = run_section(
                sec, tokens, repeats, args.outdir
            )
            if sec_summary:
                summary["summary"] = sec_summary
        except Exception as e:  # noqa: BLE001
            summary["status"] = "error"
            summary["error"] = f"{type(e).__name__}: {e}"
            sec_rows = sec_rows or []
            sec_rows.append(f"_error,{sec},{type(e).__name__},{e}")
            log.error(
                "section_error", section=sec, error=summary["error"],
                msg=f"{sec} failed: {summary['error']}",
            )
        summary["n_rows"] = len(sec_rows)
        summary["wall_seconds"] = round(time.time() - t0, 3)
        rows += sec_rows
        fresh[sec] = summary
        if summary["status"] == "ok":
            rows.append(f"_timing,{sec},{summary['wall_seconds']:.1f}s,")
            log.info(
                "section_done", section=sec,
                wall_seconds=summary["wall_seconds"],
                n_rows=summary["n_rows"],
                msg=f"{sec} done in {summary['wall_seconds']:.1f}s "
                    f"({summary['n_rows']} rows)",
            )
        if args.outdir:
            write_artifacts(args.outdir, sec, sec_rows, summary)
    rows.append(f"_timing,total,{time.time() - t_start:.1f}s,")
    problems = []
    if args.check:
        problems = check_regressions(committed, fresh)
        checked = sorted(
            sec for sec in committed
            if any(_walk_metrics(committed[sec].get("summary", {})))
        )
        rows.append(
            f"_check,sections={'+'.join(checked) or 'none'},"
            f"regressions={len(problems)},tolerance={CHECK_TOLERANCE}"
        )
        # A section with no committed BENCH_<sec>.json has nothing to gate
        # against; warn instead of silently passing so a forgotten commit
        # of the baseline artifact is visible in the check output.
        rows += [
            f"_check_warn,malformed_baseline,{sec}" for sec in malformed
        ]
        rows += [
            f"_check_warn,missing_baseline,{sec}"
            for sec in sections
            if sec not in committed and sec not in malformed
        ]
        rows += [f"_check_fail,{p}" for p in problems]
    print("\n".join(rows))
    if any(r.startswith("_error") for r in rows) or problems:
        sys.exit(1)


if __name__ == "__main__":
    main()
