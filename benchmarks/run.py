"""Benchmark harness: one section per paper table/figure + roofline.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--tokens N]``

Sections (CSV rows on stdout):
  table1  — Table 1: mean/var prediction error, WordCount + EximParse
  fig3    — Fig. 3: per-experiment predicted vs actual time
  fig4    — Fig. 4: execution-time surface over (M, R) + observed optimum
  tuner   — beyond-paper: regression autotuner vs exhaustive search
  backends— beyond-paper: reduce-backend (jnp/pallas/xla) timing comparison
  roofline— §Roofline table from the dry-run artifacts
  kernels — per-kernel microbench (us/call, interpret mode)
"""

from __future__ import annotations

import argparse
import sys
import time


def _kernel_micro() -> list[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.flash_attention import attention_ref, flash_attention
    from repro.kernels.segment_reduce import segment_reduce

    rows = ["kernel,name,us_per_call,derived"]
    rng = np.random.default_rng(0)

    def timeit(fn, *args, reps=3):
        fn(*args)  # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / reps * 1e6

    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    us_ref = timeit(lambda a, b, c: attention_ref(a, b, c, causal=True),
                    q, k, v)
    rows.append(f"kernel,attention_ref_256,{us_ref:.0f},xla_reference")
    us_pl = timeit(
        lambda a, b, c: flash_attention(a, b, c, causal=True), q, k, v
    )
    rows.append(
        f"kernel,flash_attention_256,{us_pl:.0f},"
        "interpret_mode_NOT_tpu_timing"
    )
    keys = jnp.asarray(
        np.sort(rng.integers(0, 50, size=(8, 128)).astype(np.int32), axis=1))
    vals = jnp.asarray(rng.integers(0, 9, size=(8, 128)).astype(np.int32))
    us_seg = timeit(segment_reduce, keys, vals)
    rows.append(
        f"kernel,segment_reduce_8x128,{us_seg:.0f},"
        "interpret_mode_NOT_tpu_timing"
    )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller corpora / fewer repeats")
    ap.add_argument("--tokens", type=int, default=None)
    ap.add_argument("--sections", default="all",
                    help="comma list: table1,fig3,fig4,tuner,backends,"
                         "roofline,kernels")
    args = ap.parse_args()
    tokens = args.tokens or (1 << 14 if args.quick else 1 << 16)
    repeats = 2 if args.quick else 5
    sections = (
        ["table1", "fig3", "fig4", "tuner", "backends", "roofline", "kernels"]
        if args.sections == "all" else args.sections.split(",")
    )
    rows: list[str] = []
    t_start = time.time()
    for sec in sections:
        t0 = time.time()
        try:
            if sec == "table1":
                from benchmarks import table1_prediction_error
                rows += table1_prediction_error.main(tokens, repeats)
            elif sec == "fig3":
                from benchmarks import fig3_accuracy
                rows += fig3_accuracy.main(tokens, max(2, repeats - 2))
            elif sec == "fig4":
                from benchmarks import fig4_surface
                rows += fig4_surface.main(tokens, max(2, repeats - 2))
            elif sec == "tuner":
                from benchmarks import tuner_vs_exhaustive
                rows += tuner_vs_exhaustive.main(tokens)
            elif sec == "backends":
                from benchmarks import backends_compare
                rows += backends_compare.main(tokens, max(2, repeats - 2))
            elif sec == "roofline":
                from benchmarks import roofline
                rows += roofline.main()
            elif sec == "kernels":
                rows += _kernel_micro()
            rows.append(f"_timing,{sec},{time.time() - t0:.1f}s,")
        except Exception as e:  # noqa: BLE001
            rows.append(f"_error,{sec},{type(e).__name__},{e}")
    rows.append(f"_timing,total,{time.time() - t_start:.1f}s,")
    print("\n".join(rows))
    if any(r.startswith("_error") for r in rows):
        sys.exit(1)


if __name__ == "__main__":
    main()
