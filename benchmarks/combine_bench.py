"""Benchmark section ``combine``: map-side combining's two claims.

* **contraction** — on the *skewed* WordCount corpus (Zipf word ids, the
  natural-language skew the paper's shuffle models care about), turning
  the combiner on must contract the shuffle's on-wire bytes by at least
  30% while leaving the job output **bit-exact**: the guarded metric is
  ``net_reduction`` — combiner-on ``shuffle.net_bytes`` over combiner-off
  — which must stay <= 0.7 and is gated (lower-is-better) against the
  committed value by ``run.py --check``.  The experiment runs the *live
  traced engine* both ways and asserts in-bench that the collected
  (key -> value) dicts match exactly and that neither run drops pairs,
  so the contraction is never bought with wrong answers.  The
  combiner-on per-phase trace (with its ``combine`` phase and conserved
  counters) is exported as ``combine.trace.json``.

* **scheduling** — on a *contended* fabric (``Cluster(...,
  net_capacity=...)``), a predictive policy that may choose the combiner
  per job (``predict-combine``: the category grid widens along the
  combine axis) must beat the identical policy with the axis closed
  (``predict-sjf``) on makespan: the combiner trades a little map-side
  compute for a large shuffle-byte contraction, which is exactly what a
  saturated fabric rewards.  The guarded metric is ``contended_win`` —
  combiner-blind makespan over combiner-aware makespan, > 1,
  gated higher-is-better.

* **models** — the combined shuffle-bytes curve is *nonlinear* in (M,
  size): per-task distinct keys follow the occupancy expectation
  ``V * (1 - (1 - 1/V)^s)``, not ``s`` itself.  The per-phase regression
  (same quadratic (M, R, size) basis as PR 9) fit on combiner-on
  analytic traces must still track it on held-out configs:
  ``combined_net_mae_pct`` is gated lower-is-better within a 10% band
  (against ~0.01% for the uncombined exact form — the gap is the price
  of the nonlinearity, and the reason the combiner is a *modelable*
  axis rather than a constant rescale).

The scheduling and model experiments are closed-form analytic
simulations: committed values and CI re-runs must agree exactly.  The
contraction experiment runs the real engine, but its guarded ratio is a
deterministic function of (corpus seed, config) — byte counters are
measured from the arrays, not wall-clocked.
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import heldout_configs, training_configs
from repro.cluster import (
    AnalyticOracle,
    Cluster,
    generate_workload,
    get_policy,
)

SEED = 13

# ---- contraction experiment (live engine) ---------------------------------

ENGINE_M, ENGINE_R, ENGINE_W = 8, 4, 4
ZIPF_A = 1.3
#: the gate: combiner-on net bytes over combiner-off must stay under this.
NET_REDUCTION_BAND = 0.7

# ---- scheduling experiment ------------------------------------------------

SCHED_JOBS = 32
SCHED_WORKERS = 8
#: same contended-fabric setup as the resource section: a lone shuffle
#: already stretches, overlaps stretch much harder — so halving shuffle
#: bytes is worth far more than the combine stage costs.
NET_CAPACITY = 1.5e6
SCHED_SIZES = (1 << 16, 1 << 18)
SCHED_INTERARRIVAL = 0.03

# ---- model experiment -----------------------------------------------------

MODEL_APP = "wordcount"
MODEL_SIZES = (1 << 14, 1 << 15, 1 << 16)
MODEL_WORKERS = 8
MODEL_REPEATS = 3
MODEL_NOISE = 0.03
#: heldout MAE band for the *combined* net-bytes model (percent).  The
#: occupancy curve is nonlinear in the quadratic basis, so the band is
#: wide where the uncombined exact form's is numerical (0.01%).
COMBINED_NET_BAND_PCT = 10.0


def run_contraction(tokens: int, outdir: str | None) -> dict:
    """Traced engine, combiner off vs on, same corpus/config: byte
    contraction + bit-exactness + conservation."""
    import dataclasses

    import jax.numpy as jnp

    from repro.mapreduce import JobConfig, build_job
    from repro.mapreduce.apps import wordcount
    from repro.mapreduce.datagen import wordcount_corpus
    from repro.mapreduce.engine import collect_results
    from repro.telemetry import PhaseRecorder

    app = wordcount()
    corpus = jnp.asarray(
        wordcount_corpus(tokens, app.key_space, zipf_a=ZIPF_A, seed=SEED)
    )
    cfg_off = JobConfig(
        num_mappers=ENGINE_M, num_reducers=ENGINE_R, num_workers=ENGINE_W,
        reduce_backend="jnp", combiner=False,
    )
    cfg_on = dataclasses.replace(cfg_off, combiner=True)

    results, traces = {}, {}
    for label, cfg in (("off", cfg_off), ("on", cfg_on)):
        rec = PhaseRecorder()
        job = build_job(app, cfg, int(corpus.shape[0]), recorder=rec)
        out_keys, out_vals, dropped = job(corpus)
        if int(dropped) != 0:
            raise AssertionError(
                f"combiner={label}: {int(dropped)} pairs dropped — the "
                "contraction comparison requires lossless runs"
            )
        violations = rec.last.check_conservation()
        if violations:
            raise AssertionError(
                f"combiner={label}: conservation violated: {violations}"
            )
        results[label] = collect_results(out_keys, out_vals)
        traces[label] = rec.last
    # Bit-exactness: sum is commutative+associative, so pre-aggregating
    # per task must not change a single output value.
    if results["on"] != results["off"]:
        raise AssertionError(
            "combiner changed the job output — combine is only legal "
            "because it is semantics-preserving, so this is a real bug"
        )
    net_off = traces["off"].counter("shuffle", "net_bytes")
    net_on = traces["on"].counter("shuffle", "net_bytes")
    net_reduction = net_on / max(net_off, 1e-9)
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, "combine.trace.json"), "w") as f:
            f.write(traces["on"].to_json(indent=1))
    return {
        "tokens": int(tokens),
        "mappers": ENGINE_M,
        "zipf_a": ZIPF_A,
        "net_bytes_off": int(net_off),
        "net_bytes_on": int(net_on),
        "combine_pairs_in": int(traces["on"].counter("combine", "pairs_in")),
        "combine_pairs_out": int(
            traces["on"].counter("combine", "pairs_out")
        ),
        # Guarded (lower-better) by run.py --check.
        "net_reduction": round(net_reduction, 4),
        "within_band": net_reduction <= NET_REDUCTION_BAND,
        "band": NET_REDUCTION_BAND,
        "bit_exact": True,          # asserted above, recorded for the row
        "unique_keys": len(results["on"]),
    }


def _policy(name: str, *, combiner: bool):
    kwargs = dict(
        seed=SEED,
        # One grant size so several jobs co-schedule (8 workers / grant 2
        # = 4 concurrent shuffles): the fabric, not the pool, is the
        # bottleneck under test — same setup as the resource section.
        worker_grid=(2,),
        mapper_grid=(4, 8, 16),
        reducer_grid=(4, 8, 16),
        online=False,
    )
    if combiner:
        kwargs["combiner_grid"] = (False, True)
    return get_policy(name, **kwargs)


def sched_run(policy_name: str, *, combiner: bool) -> dict:
    oracle = AnalyticOracle(noise=0.02, seed=SEED)
    jobs = generate_workload(
        SCHED_JOBS, seed=SEED, arrival="bursty",
        mean_interarrival=SCHED_INTERARRIVAL, size_range=SCHED_SIZES,
    )
    cluster = Cluster(SCHED_WORKERS, oracle, net_capacity=NET_CAPACITY)
    result = cluster.run(jobs, _policy(policy_name, combiner=combiner))
    m = result.metrics()
    return {
        "makespan_s": m["makespan_s"],
        "mean_turnaround_s": m["mean_turnaround_s"],
        "contention_s_total": round(m["contention_s_total"], 4),
        "n_contended_jobs": m["n_contended_jobs"],
        "combiner_histogram": m["combiner_histogram"],
    }


def _collect(oracle, configs, job_ids) -> tuple[np.ndarray, list]:
    """(params, traces_per_config) over the (M, R) x size grid, with the
    combiner on — every trace carries the combine phase and contracted
    shuffle counters."""
    params, traces = [], []
    for m, r in configs:
        for size in MODEL_SIZES:
            reps = []
            for j in job_ids:
                oracle.time(
                    MODEL_APP, "jnp", size, int(m), int(r),
                    MODEL_WORKERS, job_id=j, combiner=True,
                )
                reps.append(oracle.take_trace())
            params.append((float(m), float(r), float(size) / 1024.0))
            traces.append(reps)
    return np.asarray(params, dtype=np.float64), traces


def run_models() -> dict:
    from repro.telemetry.models import (
        fit_phase_models,
        targets_from_traces,
    )

    fit_kwargs = dict(degree=2, cross_terms=True, scale=True, lam=1e-8)
    train_p, train_t = _collect(
        AnalyticOracle(noise=MODEL_NOISE, seed=SEED),
        training_configs(), job_ids=range(MODEL_REPEATS),
    )
    models = fit_phase_models(
        train_p, targets_from_traces(train_t), **fit_kwargs
    )
    held_p, held_t = _collect(
        AnalyticOracle(noise=0.0, seed=SEED), heldout_configs(),
        job_ids=(0,),
    )
    truth = targets_from_traces(held_t)

    def mae_pct(phase: str, resource: str) -> float:
        pred = models.predict(phase, resource, held_p)
        true = truth[(phase, resource)]
        return float(np.mean(np.abs(pred - true) / np.abs(true)) * 100.0)

    net_mae = round(mae_pct("shuffle", "net_bytes"), 3)
    pairs_mae = round(mae_pct("combine", "pairs_out"), 3)
    return {
        "n_train": int(train_p.shape[0]),
        "n_heldout": int(held_p.shape[0]),
        # Guarded (lower-better): the combined-bytes curve is nonlinear
        # in the basis, so the band is 10%, not the exact-form 0.01%.
        "combined_net_mae_pct": net_mae,
        "combined_net_band_pct": COMBINED_NET_BAND_PCT,
        "net_within_band": net_mae <= COMBINED_NET_BAND_PCT,
        "combine_pairs_mae_pct": pairs_mae,
        "combine_time_mae_pct": round(mae_pct("combine", "time_s"), 3),
    }


def main(
    tokens: int, repeats: int, outdir: str | None = None
) -> tuple[list[str], dict]:
    """Section entry point.  ``repeats`` is unused (byte counters are
    deterministic, the simulations closed-form); ``tokens`` sizes only
    the live-engine contraction run."""
    del repeats
    contraction = run_contraction(tokens, outdir)
    blind = sched_run("predict-sjf", combiner=False)
    aware = sched_run("predict-combine", combiner=True)
    contended_win = blind["makespan_s"] / max(aware["makespan_s"], 1e-9)
    model = run_models()

    rows = [
        "combine,experiment,metric,value",
        *(
            f"combine,contraction,{k},{v}"
            for k, v in sorted(contraction.items())
        ),
        *(
            f"combine,sched_blind,{k},{v}"
            for k, v in sorted(blind.items()) if not isinstance(v, dict)
        ),
        *(
            f"combine,sched_aware,{k},{v}"
            for k, v in sorted(aware.items()) if not isinstance(v, dict)
        ),
        f"combine,sched,contended_win,{contended_win:.3f}",
        *(f"combine,models,{k},{v}" for k, v in sorted(model.items())),
    ]
    summary = {
        # net_reduction is guarded (lower-better).
        "contraction": contraction,
        "scheduling": {
            "net_capacity": NET_CAPACITY,
            "n_jobs": SCHED_JOBS,
            "workers": SCHED_WORKERS,
            "blind": blind,
            "aware": aware,
            # Guarded (higher-better): opening the combiner axis must
            # keep beating the closed-axis twin on the contended trace.
            "contended_win": round(contended_win, 3),
            "aware_wins": contended_win > 1.0,
        },
        # combined_net_mae_pct is guarded (lower-better).
        "models": model,
    }
    return rows, summary
