"""Benchmark section ``service``: burn-rate overload control vs a static cap.

One open-ended arrival stream — a diurnally modulated Poisson base
(~0.55 load) hit by two 80 s flash crowds at the diurnal *peaks*, each
pushing arrivals past 2.5x cluster capacity — is served twice by the same
FIFO policy on the same 8-worker elastic cluster, differing only in the
admission controller wrapped around it:

* **burn-control** — :class:`~repro.obs.OverloadController` driven by an
  :class:`~repro.obs.SLOMonitor` (p99 turnaround target ``SLO_TARGET_S``,
  multi-window burn-rate alarms): sheds from the queue head and opens the
  suspend-to-disk valve only while the alarm is tripped, admits
  everything otherwise;
* **static** — :class:`~repro.obs.StaticAdmission` with a fixed queue
  cap, the classic drop-tail baseline: blind to the SLO, it must hold
  the cap at all times.

The claims under test, gated by ``run.py --check`` against the committed
``BENCH_service.json``:

* burn-rate control **strictly beats** the static cap on BOTH guarded
  service metrics: exact ``p99_turnaround_s`` over all completions
  (static's pinned-at-cap crowd queue drips every crowd job out at
  cap-depth latency; the alarm sheds to ``QUEUE_FLOOR`` instead), and
  ``goodput`` — *SLO-good* tokens per second (completions within the
  target; a completion that blew the target is throughput, not goodput —
  static's crowd completions are all bad, and the alarm un-trips outside
  crowds so burn-control never sheds normal traffic);
* the burn arm's span tree, retained through ``SpanRecorder(max_jobs=…)``
  ring retention, has **zero tiling violations** on the retained window,
  and its Chrome export (with the "slo control" alarm/decision tracks)
  is well-formed.

Artifacts: ``service.trace.json`` (Chrome trace incl. control tracks)
and ``service.prom`` (Prometheus text exposition of the burn arm's
metrics registry) land next to the ``BENCH_*.json`` files for CI upload.
"""

from __future__ import annotations

import json
import math
import os

from repro.cluster import (
    AnalyticOracle,
    JobStream,
    PoissonProcess,
    diurnal_rate,
    flash_crowd_rate,
    get_policy,
)
from repro.elastic import ElasticCluster
from repro.obs import (
    ClusterMetrics,
    ControlledPolicy,
    OverloadController,
    SLOMonitor,
    SLOPolicy,
    SpanRecorder,
    StaticAdmission,
)

SEED = 11
WORKERS = 8
N_JOBS = 2000            #: stream bound (jobs admitted-or-rejected)

# ---- arrival stream -------------------------------------------------------
# Base ~0.85 jobs/s against ~1.8 jobs/s service capacity (2 concurrent
# 4-worker grants, ~1.1 s mean service); crowds multiply the diurnal rate
# 4.5x right at its peaks — >2.5x capacity, the provisioning stress case.

BASE_RATE = 0.85
DIURNAL_AMPLITUDE = 0.3
DIURNAL_PERIOD_S = 600.0
CROWDS = [(700.0, 780.0, 4.5), (1300.0, 1380.0, 4.5)]
PEAK_RATE = BASE_RATE * (1.0 + DIURNAL_AMPLITUDE) * 4.5

# ---- SLO + controllers ----------------------------------------------------

SLO_TARGET_S = 6.0       #: good = turnaround within this
SLO_OBJECTIVE = 0.95     #: 95% of completions must be good
FAST_WINDOW_S = 15.0
SLOW_WINDOW_S = 60.0
TRIP_BURN = 1.5
CLEAR_BURN = 0.5
MIN_EVENTS = 12
QUEUE_FLOOR = 4
MAX_SUSPENDED = 1
STATIC_CAP = 12

METRICS_WINDOW_S = 60.0
RETAIN_JOBS = 200        #: SpanRecorder ring retention


def make_stream() -> JobStream:
    rate = flash_crowd_rate(
        diurnal_rate(
            BASE_RATE, amplitude=DIURNAL_AMPLITUDE,
            period_s=DIURNAL_PERIOD_S,
        ),
        CROWDS,
    )
    return JobStream(
        PoissonProcess(rate, peak_rate=PEAK_RATE, seed=SEED), seed=SEED
    )


def exact_quantile(xs: list[float], q: float) -> float:
    """The same ceil-index order statistic the P² estimator targets."""
    xs = sorted(xs)
    return xs[min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))]


def run_arm(controller) -> tuple[object, ClusterMetrics, dict]:
    """One service run under ``controller``; returns (result, metrics,
    service-level measurements)."""
    metrics = ClusterMetrics(window_s=METRICS_WINDOW_S)
    cluster = ElasticCluster(
        WORKERS, AnalyticOracle(noise=0.02, seed=SEED), metrics=metrics,
    )
    policy = ControlledPolicy(get_policy("fifo-static"), controller)
    result = cluster.run_service(make_stream(), policy, until_jobs=N_JOBS)

    done = [r for r in result.records if r.completed]
    turnarounds = [r.turnaround for r in done]
    t0 = min(r.spec.arrival for r in result.records)
    t_end = max(r.finish for r in done)
    good = [r for r in done if r.turnaround <= SLO_TARGET_S]
    measurements = {
        "n_arrived": len(result.records),
        "n_completed": len(done),
        "n_rejected": sum(1 for r in result.records if not r.admitted),
        "n_good": len(good),
        "p50_turnaround_s": round(exact_quantile(turnarounds, 0.50), 3),
        "p99_turnaround_s": round(exact_quantile(turnarounds, 0.99), 3),
        # SLO-good tokens per second: the service metric the controller
        # optimizes — bad completions spent capacity without serving
        # anyone within the target.
        "goodput": round(sum(r.spec.size for r in good) / (t_end - t0), 1),
        "n_control_actions": len(controller.log),
        "n_sheds": sum(1 for a in controller.log if a.action == "shed"),
        "n_suspends": sum(
            1 for a in controller.log if a.action == "suspend"
        ),
    }
    return result, metrics, measurements


def main(
    tokens: int, repeats: int, outdir: str | None = None
) -> tuple[list[str], dict]:
    """Section entry point.  ``tokens`` / ``repeats`` are unused: the
    stream, both controllers, and the oracle are fully seeded, so the
    committed values and every CI re-run must agree exactly."""
    del tokens, repeats

    monitor = SLOMonitor(
        SLOPolicy(SLO_TARGET_S, objective=SLO_OBJECTIVE),
        fast_window_s=FAST_WINDOW_S, slow_window_s=SLOW_WINDOW_S,
        trip_burn=TRIP_BURN, clear_burn=CLEAR_BURN, min_events=MIN_EVENTS,
    )
    burn_ctrl = OverloadController(
        monitor, queue_floor=QUEUE_FLOOR, max_suspended=MAX_SUSPENDED,
    )
    result_b, metrics_b, burn = run_arm(burn_ctrl)
    _result_s, _metrics_s, static = run_arm(StaticAdmission(STATIC_CAP))

    recorder = SpanRecorder(max_jobs=RETAIN_JOBS)
    recorder.record(result_b, control_log=burn_ctrl.log)
    violations = recorder.check()
    doc = recorder.chrome()
    issues = recorder.validate()

    if outdir:
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, "service.trace.json"), "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        metrics_b.registry.save_prom(os.path.join(outdir, "service.prom"))

    budget = monitor.budget()
    summary = {
        "config": {
            "n_jobs": N_JOBS,
            "workers": WORKERS,
            "crowds": [list(c) for c in CROWDS],
            "slo_target_s": SLO_TARGET_S,
            "slo_objective": SLO_OBJECTIVE,
            "queue_floor": QUEUE_FLOOR,
            "static_cap": STATIC_CAP,
        },
        "burn_control": burn,
        "static": static,
        # Guarded by run.py --check (p99 up = regression, goodput down =
        # regression) against the committed baseline.
        "p99_turnaround_s": burn["p99_turnaround_s"],
        "goodput": burn["goodput"],
        "beats_static_p99": (
            burn["p99_turnaround_s"] < static["p99_turnaround_s"]
        ),
        "beats_static_goodput": burn["goodput"] > static["goodput"],
        "alarms": [
            {"t": round(a.t, 3), "event": a.event,
             "burn_fast": round(a.burn_fast, 3),
             "burn_slow": round(a.burn_slow, 3)}
            for a in monitor.alarms
        ],
        "budget_remaining_frac": round(budget["remaining_frac"], 4),
        "spans": {
            "retained_jobs": len(recorder.roots[0].children),
            "dropped_jobs": recorder.n_dropped_jobs,
            "dropped_spans": recorder.n_dropped_spans,
            "tiling_violations": len(violations),
            "chrome_issues": len(issues),
            "n_trace_events": len(doc["traceEvents"]),
        },
    }
    rows = [
        "service,arm,metric,value",
        *(f"service,burn_control,{k},{v}" for k, v in sorted(burn.items())),
        *(f"service,static,{k},{v}" for k, v in sorted(static.items())),
        *(
            f"service,spans,{k},{v}"
            for k, v in sorted(summary["spans"].items())
        ),
    ]
    return rows, summary
