"""Benchmark section ``cluster``: predictive scheduling vs the FIFO baseline.

Runs every registered policy over the *same* deterministic heterogeneous
trace (≥ 50 jobs by default) on the analytic oracle and reports makespan,
mean wait/turnaround, utilization, SLO attainment, and the in-trace
prediction-error trajectory (first-half vs second-half MAE — the online
refinement effect).  CSV rows go to stdout like every other section; the
summary dict feeds ``BENCH_cluster.json``.
"""

from __future__ import annotations

from repro.cluster import (
    AnalyticOracle,
    Cluster,
    POLICIES,
    PredictivePolicy,
    assign_deadlines,
    generate_workload,
    get_policy,
)

N_JOBS = 60
WORKERS = 16


def run_trace(
    *,
    n_jobs: int = N_JOBS,
    workers: int = WORKERS,
    arrival: str = "poisson",
    mean_interarrival: float = 0.12,
    size_range: tuple[int, int] = (1 << 14, 1 << 18),
    deadline_fraction: float = 0.6,
    slack_range: tuple[float, float] = (1.2, 6.0),
    noise: float = 0.02,
    seed: int = 1,
    policies=None,
) -> dict[str, dict]:
    """Run each policy over one shared trace; return metrics per policy."""
    oracle = AnalyticOracle(noise=noise, seed=seed)
    jobs = generate_workload(
        n_jobs, seed=seed, arrival=arrival,
        mean_interarrival=mean_interarrival, size_range=size_range,
    )
    jobs = assign_deadlines(
        jobs, lambda j: oracle.nominal_time(j.app, j.size),
        slack_range=slack_range, fraction=deadline_fraction, seed=seed + 1,
    )
    cluster = Cluster(workers, oracle)
    out = {}
    # Default: every registered policy (ARCHITECTURE.md's registration
    # recipe puts user policies in the comparison automatically), with the
    # baseline pinned first.
    if policies is None:
        policies = ["fifo-static"] + sorted(
            n for n in POLICIES if n != "fifo-static"
        )
    for name in policies:
        # Only the predictive base class takes seed=; a user-registered
        # minimal SchedulingPolicy must construct bare.
        predictive = issubclass(POLICIES[name], PredictivePolicy)
        kwargs = {"seed": seed} if predictive else {}
        result = cluster.run(jobs, get_policy(name, **kwargs))
        out[name] = result.metrics()
    return out


def main(tokens: int, repeats: int) -> tuple[list[str], dict]:
    """Section entry point; (tokens, repeats) follow the harness convention
    (tokens scales the max job size, repeats is unused — one shared trace
    keeps every policy comparable)."""
    del repeats
    size_hi = max(1 << 15, tokens)
    metrics = run_trace(size_range=(1 << 14, size_hi))
    rows = [
        "cluster,policy,makespan_s,mean_wait_s,mean_turnaround_s,"
        "utilization,slo_attainment,n_rejected,pred_mae_pct,"
        "pred_mae_pct_first_half,pred_mae_pct_second_half"
    ]

    def fmt(x, nd=3):
        return "" if x is None else f"{x:.{nd}f}"

    for name, m in metrics.items():
        rows.append(
            f"cluster,{name},{fmt(m['makespan_s'])},{fmt(m['mean_wait_s'])},"
            f"{fmt(m['mean_turnaround_s'])},{fmt(m['utilization'])},"
            f"{fmt(m['slo_attainment'])},{m['n_rejected']},"
            f"{fmt(m['pred_mae_pct'])},{fmt(m['pred_mae_pct_first_half'])},"
            f"{fmt(m['pred_mae_pct_second_half'])}"
        )

    baseline = metrics["fifo-static"]["makespan_s"]
    predictive = {
        n: m for n, m in metrics.items() if n != "fifo-static"
    }
    best_name = min(predictive, key=lambda n: predictive[n]["makespan_s"])
    # Telemetry-driven policy: on an unconstrained fabric (the default)
    # predict-resource must match predict-sjf decision-for-decision — any
    # makespan gap is a regression.
    resource_vs_sjf = None
    if "predict-resource" in metrics and "predict-sjf" in metrics:
        ms_res = metrics["predict-resource"]["makespan_s"]
        ms_sjf = metrics["predict-sjf"]["makespan_s"]
        resource_vs_sjf = {
            "makespan_resource_s": ms_res,
            "makespan_sjf_s": ms_sjf,
            "no_regression": ms_res <= ms_sjf * 1.001,
        }
    # Depth-aware policy: the histogram of chosen overlap depths is the
    # evidence that depth is picked per job, not pinned globally.
    pipeline_depths = None
    if "predict-pipeline" in metrics:
        pipeline_depths = metrics["predict-pipeline"]["depth_histogram"]
    refined = [
        (n, m) for n, m in predictive.items()
        if m["pred_mae_pct_first_half"] is not None
        and m["pred_mae_pct_second_half"] is not None
    ]
    summary = {
        "n_jobs": N_JOBS,
        "workers": WORKERS,
        "per_policy": metrics,
        "baseline_makespan_s": baseline,
        "best_predictive_policy": best_name,
        "best_predictive_makespan_s": predictive[best_name]["makespan_s"],
        "predictive_beats_baseline_makespan": (
            predictive[best_name]["makespan_s"] < baseline
        ),
        "resource_vs_sjf": resource_vs_sjf,
        "pipeline_depth_histogram": pipeline_depths,
        "online_refinement": {
            n: {
                "mae_pct_first_half": m["pred_mae_pct_first_half"],
                "mae_pct_second_half": m["pred_mae_pct_second_half"],
                "improved": (
                    m["pred_mae_pct_second_half"]
                    < m["pred_mae_pct_first_half"]
                ),
            }
            for n, m in refined
        },
    }
    if pipeline_depths is not None:
        hist = "+".join(
            f"d{d}:{n}" for d, n in sorted(pipeline_depths.items())
        )
        rows.append(f"cluster,_depths,predict-pipeline,{hist}")
    rows.append(
        f"cluster,_summary,best={best_name},"
        f"beats_baseline={summary['predictive_beats_baseline_makespan']},"
        f"baseline_makespan={baseline:.3f},"
        f"best_makespan={predictive[best_name]['makespan_s']:.3f}"
    )
    return rows, summary
