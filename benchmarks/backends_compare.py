"""Per-backend timing comparison (beyond-paper: the execution strategy as a
configuration axis).

For WordCount and Exim parse, times every registered reduce backend on a
small (M, R) grid, verifies all backends agree with the ``jnp`` reference
output, and reports the measured-best backend per application.

CSV rows:
  backends,<app>,<backend>,<M>,<R>,<mean_s>
  backends,<app>,equivalence,ok,,
  backends,<app>,best,<backend>,,
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import JobRunner, make_app
from repro.core.profiler import profile_categorical
from repro.mapreduce import (
    JobConfig,
    REDUCE_BACKENDS,
    build_job,
    collect_results,
)

# Partition capacity grows ~ tokens/R; the Pallas kernel builds a (C, C)
# one-hot per partition, so keep this section's corpora modest.
MAX_TOKENS = 1 << 13
CONFIGS = np.asarray([[8.0, 8.0], [16.0, 16.0]])


def _check_equivalence(app, corpus) -> None:
    ref = None
    for name in sorted(REDUCE_BACKENDS):
        cfg = JobConfig(num_mappers=8, num_reducers=8, reduce_backend=name)
        ok, ov, dropped = build_job(app, cfg, len(corpus))(corpus)
        got = (collect_results(ok, ov), int(dropped))
        if ref is None:
            ref = got
        elif got != ref:
            raise AssertionError(f"backend {name} diverges from reference")


def main(tokens: int, repeats: int = 2) -> list[str]:
    tokens = min(tokens, MAX_TOKENS)
    rows = ["backends,app,backend,M,R,mean_s"]
    for app_name in ("wordcount", "eximparse"):
        app, corpus = make_app(app_name, tokens)
        _check_equivalence(app, corpus)
        rows.append(f"backends,{app_name},equivalence,ok,,")
        runners = {
            name: JobRunner(app, corpus, reduce_backend=name)
            for name in sorted(REDUCE_BACKENDS)
        }
        profiles = profile_categorical(
            runners, CONFIGS, repeats=repeats,
            param_names=("mappers", "reducers"),
        )
        mean_by_backend = {}
        for name, prof in profiles.items():
            for (m, r), t in zip(prof.params, prof.times):
                rows.append(
                    f"backends,{app_name},{name},{int(m)},{int(r)},{t:.4f}"
                )
            mean_by_backend[name] = float(prof.times.mean())
        best = min(mean_by_backend, key=mean_by_backend.get)
        rows.append(f"backends,{app_name},best,{best},,")
    return rows
