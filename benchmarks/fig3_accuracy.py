"""Figure 3 reproduction: per-experiment predicted vs actual execution time
for WordCount and Exim Mainlog parsing (prediction phase, unseen configs)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import heldout_configs, profile_app
from repro.core import fit


def main(tokens: int = 1 << 16, repeats: int = 3) -> list[str]:
    out = ["fig3,app,mappers,reducers,actual_s,predicted_s,err_pct"]
    for app_name in ("wordcount", "eximparse"):
        runner, prof = profile_app(
            app_name, tokens=tokens, repeats=repeats
        )
        model = fit(prof.params, prof.times)
        for cfg_row in heldout_configs():
            actual = float(np.mean([runner(cfg_row) for _ in range(repeats)]))
            pred = float(np.asarray(model.predict(cfg_row)).ravel()[0])
            err = abs(pred - actual) / actual * 100
            out.append(
                f"fig3,{app_name},{int(cfg_row[0])},{int(cfg_row[1])},"
                f"{actual:.5f},{pred:.5f},{err:.2f}"
            )
    return out


if __name__ == "__main__":
    print("\n".join(main()))
