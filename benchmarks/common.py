"""Shared profiling harness for the paper-reproduction benchmarks.

This is the FAITHFUL experimental setup, scaled to the host: the paper runs
WordCount and Exim Mainlog parsing on a 4-node Hadoop cluster over 8 GB with
20 (mappers, reducers) settings in [5, 40], 5 repeats each; we run the same
two applications on the TPU-native MapReduce engine over a synthetic corpus
(size set by --tokens), the same parameter ranges, wall-clocked after one
warmup run (compile excluded — Hadoop's job-setup is likewise outside the
paper's modeled time).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import profiler
from repro.mapreduce import (
    JobConfig,
    build_job,
    eximparse,
    exim_mainlog,
    wordcount,
    wordcount_corpus,
)

DEFAULT_TOKENS = 1 << 16
PARAM_RANGE = (5, 40)


def make_app(name: str, tokens: int, seed: int = 0):
    if name == "wordcount":
        corpus = wordcount_corpus(tokens, vocab_size=4096, seed=seed)
        return wordcount(4096), corpus
    if name == "eximparse":
        corpus = exim_mainlog(tokens, n_transactions=1024, seed=seed)
        return eximparse(1024), corpus
    raise ValueError(name)


class JobRunner:
    """Compile-cached runner: time(config) for one application.

    ``cfg_kwargs`` forwards extra JobConfig fields (e.g.
    ``reduce_backend="pallas"``), making the execution backend one more
    profiled axis — build one runner per category and hand the set to
    ``core.profiler.profile_categorical`` / ``core.tuner.tune_categorical``.
    """

    def __init__(self, app, corpus, *, warmup: int = 1, **cfg_kwargs):
        self.app = app
        self.corpus = corpus
        self.warmup = warmup
        self.cfg_kwargs = cfg_kwargs
        self._cache: dict[tuple[int, int], object] = {}

    def __call__(self, config) -> float:
        M, R = int(round(config[0])), int(round(config[1]))
        key = (M, R)
        if key not in self._cache:
            job = build_job(
                self.app,
                JobConfig(num_mappers=M, num_reducers=R, **self.cfg_kwargs),
                len(self.corpus),
            )
            for _ in range(self.warmup):
                jax.block_until_ready(job(self.corpus))
            self._cache[key] = job
        job = self._cache[key]
        t0 = time.perf_counter()
        jax.block_until_ready(job(self.corpus))
        return time.perf_counter() - t0


def training_configs(n: int = 20, seed: int = 0) -> np.ndarray:
    """The paper's 20 profiled settings: spread over [5,40]^2."""
    rng = np.random.default_rng(seed)
    lo, hi = PARAM_RANGE
    # stratified: 16 grid points + 4 random fill-ins
    grid_axis = np.linspace(lo, hi, 4).round()
    pts = [(m, r) for m in grid_axis for r in grid_axis]
    while len(pts) < n:
        pts.append(tuple(rng.integers(lo, hi + 1, 2).tolist()))
    return np.asarray(pts[:n], dtype=np.float64)


def heldout_configs(n: int = 8, seed: int = 123) -> np.ndarray:
    """Random unseen settings for the prediction phase."""
    rng = np.random.default_rng(seed)
    lo, hi = PARAM_RANGE
    return rng.integers(lo, hi + 1, size=(n, 2)).astype(np.float64)


def profile_app(name: str, *, tokens: int = DEFAULT_TOKENS,
                configs: np.ndarray | None = None, repeats: int = 5,
                verbose: bool = False):
    app, corpus = make_app(name, tokens)
    runner = JobRunner(app, corpus)
    configs = training_configs() if configs is None else configs
    return runner, profiler.profile_experiments(
        runner, configs, repeats=repeats,
        param_names=("mappers", "reducers"), verbose=verbose,
    )
