"""Benchmark section ``pipeline``: the pipelined execution mode and the
overlap-depth model axis (beyond-paper: software pipelining as a
configuration parameter).

Part A — engine wall-clock: fused vs ``plan.pipelined(depth=D)`` on
shuffle-heavy WordCount configs, asserting bit-exact outputs and reporting
the measured speedup per depth.  The headline config (all_to_all shuffle,
high wave count) is where the compute/commit pipeline pays; a contrast
config where it does *not* pay is benched too — the point of the axis is
that depth must be chosen per job, not pinned.

Part B — model axis: overlap depth joins the paper's methodology as a
categorical axis.  ``tune_categorical`` fits one polynomial model per
depth over (M, R, W) samples of the analytic oracle and argmins jointly;
heldout noiseless MAE per depth shows the depth categories model as well
as the paper's M/R axes do.

CSV rows:
  pipeline,<config>,<mode>,<depth>,<best_s>,<speedup>
  pipeline,<config>,bit_exact,ok,,
  pipeline,depth_model,<cat>,mae_pct,<val>,
  pipeline,_summary,speedup=<x>,target=1.15,meets_target=<bool>

The JSON summary's top-level ``speedup`` is a --check guarded metric
(lower than committed by >25% fails); per-config values live under
``speedup_x`` keys so single-config noise never gates.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.cluster import AnalyticOracle
from repro.core.tuner import tune_categorical
from repro.mapreduce import (
    ExecutionPlan,
    JobConfig,
    wordcount,
    wordcount_corpus,
)

# Part A pins the corpus size: the fused-vs-pipelined comparison is
# wave-count driven (the pipeline amortizes per-wave loop overhead), so the
# committed artifact and the CI smoke (--tokens 8192) must measure the
# *same* workload or the --check gate compares different experiments.
TOKENS_A = 1 << 13
VOCAB = 211
DEPTHS = (2, 4, 8)
TARGET_SPEEDUP = 1.15

#: (name, JobConfig kwargs).  First entry is the headline: all_to_all with
#: 128 single-worker map waves — maximal wave-loop overhead for fused, so
#: maximal headroom for the pipeline, which retires waves ``depth`` at a
#: time.  The contrast entries (paper-range shapes, wide waves) show the
#: axis is non-trivial: near-1x or below, so depth must be *chosen*.
CONFIGS = (
    ("a2a_128x64_w1", dict(num_mappers=128, num_reducers=64, num_workers=1,
                           shuffle_backend="all_to_all",
                           capacity_factor=1.0)),
    ("a2a_32x32_w2", dict(num_mappers=32, num_reducers=32, num_workers=2,
                          shuffle_backend="all_to_all",
                          capacity_factor=8.0)),
    ("lex_40x40_w4", dict(num_mappers=40, num_reducers=40, num_workers=4,
                          shuffle_backend="lexsort")),
)
HEADLINE = CONFIGS[0][0]


def _assert_bit_exact(ref, got, name: str, depth: int) -> None:
    for i, (a, b) in enumerate(zip(ref, got)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise AssertionError(
                f"pipelined depth={depth} diverges from fused on "
                f"{name} (output {i})"
            )


def bench_engine(tokens: int, repeats: int) -> tuple[list[str], dict]:
    corpus = wordcount_corpus(tokens, vocab_size=VOCAB, seed=3)
    app = wordcount(VOCAB)
    reps = max(10, 2 * repeats)
    rows = []
    per_config = {}
    for name, kwargs in CONFIGS:
        plan = ExecutionPlan(app, JobConfig(**kwargs), tokens)
        modes = {1: plan.fused()}
        ref = modes[1](corpus)
        for d in DEPTHS:
            modes[d] = plan.pipelined(depth=d)
            _assert_bit_exact(ref, modes[d](corpus), name, d)
        # Interleaved min-of-N: round-robin the modes inside each rep so a
        # transient host stall penalizes all of them, not whichever mode
        # happened to be running (single-core container, noisy neighbors).
        for fn in modes.values():
            jax.block_until_ready(fn(corpus))
        best = {d: float("inf") for d in modes}
        for _ in range(reps):
            for d, fn in modes.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn(corpus))
                best[d] = min(best[d], time.perf_counter() - t0)
        t_fused = best[1]
        rows.append(f"pipeline,{name},fused,1,{t_fused:.5f},1.000")
        entry = {"fused_s": t_fused, "pipelined_s": {}, "speedup_x": {}}
        for d in DEPTHS:
            sp = t_fused / best[d]
            entry["pipelined_s"][str(d)] = best[d]
            entry["speedup_x"][str(d)] = sp
            rows.append(
                f"pipeline,{name},pipelined,{d},{best[d]:.5f},{sp:.3f}"
            )
        rows.append(f"pipeline,{name},bit_exact,ok,,")
        per_config[name] = entry
    return rows, per_config


def bench_depth_model(seed: int = 7) -> tuple[list[str], dict]:
    """Fit one model per overlap depth on analytic-oracle profiles and
    measure heldout noiseless MAE — the depth analogue of Table 1."""
    oracle = AnalyticOracle(noise=0.02, seed=seed)
    size = 1 << 16

    def run_fn(depth):
        def f(row, _c=[0]):  # job_id varies so noise draws are iid
            _c[0] += 1
            return oracle.time(
                "wordcount", "jnp", size,
                int(round(row[0])), int(round(row[1])),
                int(round(row[2])), job_id=_c[0], depth=depth,
            )
        return f

    rng = np.random.default_rng(seed)
    m = rng.integers(5, 41, size=160)
    r = rng.integers(5, 41, size=160)
    w = rng.choice([2, 4, 8], size=160)
    space = np.stack([m, r, w], axis=1).astype(np.float64)
    depths = (1,) + DEPTHS
    result = tune_categorical(
        {f"d{d}": run_fn(d) for d in depths}, space,
        n_samples=48, seed=seed,
    )

    heldout = np.stack(
        [rng.integers(5, 41, size=16), rng.integers(5, 41, size=16),
         rng.choice([2, 4, 8], size=16)], axis=1,
    ).astype(np.float64)
    rows = []
    mae = {}
    for d in depths:
        model = result.per_category[f"d{d}"].model
        errs = []
        for row in heldout:
            truth = oracle.time(
                "wordcount", "jnp", size, int(row[0]), int(row[1]),
                int(row[2]), depth=d, _noiseless=True,
            )
            pred = float(np.asarray(model.predict(row)).ravel()[0])
            errs.append(abs(pred - truth) / max(truth, 1e-12) * 100)
        mae[f"d{d}"] = float(np.mean(errs))
        rows.append(f"pipeline,depth_model,d{d},mae_pct,{mae[f'd{d}']:.2f},")
    rows.append(
        f"pipeline,depth_model,best_category,{result.best_category},,"
    )
    return rows, {
        "mae_pct": mae,
        "best_category": result.best_category,
        # "comparable to the M/R axes": the depth>1 models must predict
        # about as well as the depth-1 (paper-axes-only) model does.
        "mae_comparable": all(
            mae[f"d{d}"] <= max(2.0 * mae["d1"], mae["d1"] + 5.0)
            for d in DEPTHS
        ),
    }


def main(tokens: int, repeats: int) -> tuple[list[str], dict]:
    del tokens  # Part A is pinned (see TOKENS_A); Part B is analytic
    rows = ["pipeline,config,mode,depth,best_s,speedup"]
    eng_rows, per_config = bench_engine(TOKENS_A, repeats)
    rows += eng_rows
    model_rows, depth_model = bench_depth_model()
    rows += model_rows

    headline = max(per_config[HEADLINE]["speedup_x"].values())
    summary = {
        "tokens": TOKENS_A,
        "headline_config": HEADLINE,
        "speedup": headline,                  # --check guarded metric
        "target": TARGET_SPEEDUP,
        "meets_target": headline >= TARGET_SPEEDUP,
        "bit_exact": True,                    # bench_engine raises otherwise
        "per_config": per_config,
        "depth_model": depth_model,
    }
    rows.append(
        f"pipeline,_summary,speedup={headline:.3f},"
        f"target={TARGET_SPEEDUP},meets_target={summary['meets_target']}"
    )
    return rows, summary


if __name__ == "__main__":
    out_rows, out_summary = main(TOKENS_A, 3)
    print("\n".join(out_rows))
