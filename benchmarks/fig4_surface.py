"""Figure 4 reproduction: total execution time vs (#mappers, #reducers)
surface for both applications — the dependency the paper models.

The paper's observation to reproduce: the surface is smooth enough for a
per-parameter cubic, non-monotonic, with a platform-specific optimum.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import make_app, JobRunner, DEFAULT_TOKENS
from repro.core import grid


def main(tokens: int = DEFAULT_TOKENS, repeats: int = 3) -> list[str]:
    out = ["fig4,app,mappers,reducers,mean_s,std_s"]
    surface = grid([(5, 40, 7), (5, 40, 7)])  # 6x6 sample of the paper grid
    optima = []
    for app_name in ("wordcount", "eximparse"):
        app, corpus = make_app(app_name, tokens)
        runner = JobRunner(app, corpus)
        best = (None, np.inf)
        for row in surface:
            ts = [runner(row) for _ in range(repeats)]
            m, s = float(np.mean(ts)), float(np.std(ts))
            out.append(
                f"fig4,{app_name},{int(row[0])},{int(row[1])},"
                f"{m:.5f},{s:.5f}"
            )
            if m < best[1]:
                best = (row, m)
        optima.append(
            f"fig4_optimum,{app_name},{int(best[0][0])},{int(best[0][1])},"
            f"{best[1]:.5f},"
        )
    return out + optima


if __name__ == "__main__":
    print("\n".join(main()))
