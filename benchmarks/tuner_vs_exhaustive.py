"""Beyond-paper: the paper's model as a configuration AUTOTUNER.

The paper suggests using predicted execution times to make schedulers
smarter; this benchmark closes the loop: sample a subset of the (M, R)
space, fit the model, argmin the prediction over the whole space, and
compare against exhaustive search.  Reported: profiling-cost savings vs
regret (% time lost relative to the true optimum).

Standalone, the overlap-depth axis joins the tuned space as categories
(one model per depth, joint argmin — the same treatment backends get):

    PYTHONPATH=src python -m benchmarks.tuner_vs_exhaustive \
        --overlap-depth 1,2,4
"""

from __future__ import annotations


from benchmarks.common import make_app, JobRunner, DEFAULT_TOKENS
from repro.core import grid, tune, validate
from repro.core.tuner import tune_categorical


def main(tokens: int = DEFAULT_TOKENS,
         depth_grid: tuple[int, ...] = (1,)) -> list[str]:
    out = [
        "tuner,app,space_size,profiles_used,chosen_m,chosen_r,"
        "chosen_depth,chosen_time_s,optimum_time_s,regret_pct"
    ]
    space = grid([(5, 40, 5), (5, 40, 5)])  # 64 configs
    for app_name in ("wordcount", "eximparse"):
        app, corpus = make_app(app_name, tokens)
        if tuple(depth_grid) == (1,):
            runner = JobRunner(app, corpus)
            result = tune(runner, space, n_samples=24, repeats=2, seed=0)
            depth = 1
        else:
            runners = {
                f"d{d}": JobRunner(app, corpus, overlap_depth=d)
                for d in depth_grid
            }
            cat = tune_categorical(
                runners, space, n_samples=24, repeats=2, seed=0
            )
            result = cat.per_category[cat.best_category]
            runner = runners[cat.best_category]
            depth = int(cat.best_category.lstrip("d"))
        result = validate(result, runner, space, repeats=2)
        out.append(
            f"tuner,{app_name},{len(space)},"
            f"{len(result.sampled_configs)},"
            f"{int(result.best_config[0])},{int(result.best_config[1])},"
            f"{depth},"
            f"{result.measured_best_time:.5f},"
            f"{result.true_optimum_time:.5f},"
            f"{result.regret_pct:.2f}"
        )
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=DEFAULT_TOKENS)
    ap.add_argument("--overlap-depth", default="1", metavar="D1,D2,...",
                    help="comma list of overlap depths to tune across "
                         "(each is one categorical model; joint argmin)")
    args = ap.parse_args()
    depths = tuple(
        int(d) for d in args.overlap_depth.split(",") if d.strip()
    )
    print("\n".join(main(args.tokens, depths)))
