"""Beyond-paper: the paper's model as a configuration AUTOTUNER.

The paper suggests using predicted execution times to make schedulers
smarter; this benchmark closes the loop: sample a subset of the (M, R)
space, fit the model, argmin the prediction over the whole space, and
compare against exhaustive search.  Reported: profiling-cost savings vs
regret (% time lost relative to the true optimum).
"""

from __future__ import annotations


from benchmarks.common import make_app, JobRunner, DEFAULT_TOKENS
from repro.core import grid, tune, validate


def main(tokens: int = DEFAULT_TOKENS) -> list[str]:
    out = [
        "tuner,app,space_size,profiles_used,chosen_m,chosen_r,"
        "chosen_time_s,optimum_time_s,regret_pct"
    ]
    space = grid([(5, 40, 5), (5, 40, 5)])  # 64 configs
    for app_name in ("wordcount", "eximparse"):
        app, corpus = make_app(app_name, tokens)
        runner = JobRunner(app, corpus)
        result = tune(runner, space, n_samples=24, repeats=2, seed=0)
        result = validate(result, runner, space, repeats=2)
        out.append(
            f"tuner,{app_name},{len(space)},"
            f"{len(result.sampled_configs)},"
            f"{int(result.best_config[0])},{int(result.best_config[1])},"
            f"{result.measured_best_time:.5f},"
            f"{result.true_optimum_time:.5f},"
            f"{result.regret_pct:.2f}"
        )
    return out


if __name__ == "__main__":
    print("\n".join(main()))
