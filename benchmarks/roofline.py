"""Roofline table: reads the dry-run artifacts (experiments/dryrun) and
emits the §Roofline rows — per (arch x shape x mesh): the three terms,
dominant bottleneck, MODEL_FLOPS ratio, and roofline fraction."""

from __future__ import annotations

import glob
import json
import os



def load_reports(dryrun_dir: str = "experiments/dryrun") -> list[dict]:
    reports = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*", "*.json"))):
        with open(path) as f:
            r = json.load(f)
        r["_mesh_name"] = os.path.basename(os.path.dirname(path))
        reports.append(r)
    return reports


def main(dryrun_dir: str = "experiments/dryrun") -> list[str]:
    out = [
        "roofline,mesh,arch,shape,dominant,compute_s,memory_s,"
        "collective_s,step_s_no_overlap,useful_flops_ratio,"
        "roofline_fraction,peak_gib_per_dev,fits_16gib"
    ]
    reports = load_reports(dryrun_dir)
    if not reports:
        out.append("roofline,NO_DRYRUN_ARTIFACTS_FOUND,run "
                   "`python -m repro.launch.dryrun` first,,,,,,,,,")
        return out
    for r in reports:
        roof = r["roofline"]
        meta = r["meta"]
        peak_gib = r["memory"]["peak_bytes"] / 2**30
        out.append(
            f"roofline,{r['_mesh_name']},{meta['arch']},{meta['shape']},"
            f"{roof['dominant']},{roof['compute_s']:.4f},"
            f"{roof['memory_s']:.4f},{roof['collective_s']:.4f},"
            f"{roof['step_time_no_overlap']:.4f},"
            f"{(roof.get('useful_ratio') or 0):.3f},"
            f"{(roof.get('roofline_fraction') or 0):.4f},"
            f"{peak_gib:.2f},{peak_gib <= 16.0}"
        )
    return out


if __name__ == "__main__":
    print("\n".join(main()))
